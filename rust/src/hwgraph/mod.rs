//! HW-GRAPH: the multi-layer, graph-based hardware representation (§3.3).
//!
//! A node is (i) a computational unit, (ii) a storage unit, (iii) a dedicated
//! controller, (iv) an abstract component with unknown internals, or (v) a
//! *group* encapsulating a sub-graph (a device, a cluster, the root).
//! Edges are typed interconnects. Cross-layer "refines" links relate the
//! abstract and detailed versions of a component (the red dashed connections
//! of Fig. 4a). Containment (`parent`) builds the hierarchy the Orchestrator
//! mirrors (Fig. 4b).
//!
//! Everything the Traverser and Orchestrator do is algorithmic over this
//! graph: `compute_path` (single-source shortest path from a PU to the
//! storage/controller resources it relies on), `shared_resources`
//! (path intersection — the mechanism that uncovers e.g. DLA+PVA sharing
//! SRAM and LPDDR), `pus_in` (group traversal), and `device_of`.

mod build;
mod path;
pub mod presets;

pub use build::GraphBuilder;
pub use path::sssp_invocations;

use std::collections::BTreeMap;

/// Index of a node in the graph arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u32);

/// Index of an edge in the graph arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct EdgeId(pub u32);

/// Processing-unit classes found across the paper's testbed (Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PuClass {
    CpuCore,
    Gpu,
    /// deep learning accelerator (Jetson DLA)
    Dla,
    /// programmable vision accelerator
    Pva,
    /// video image compositor
    Vic,
}

impl PuClass {
    pub fn name(&self) -> &'static str {
        match self {
            PuClass::CpuCore => "cpu",
            PuClass::Gpu => "gpu",
            PuClass::Dla => "dla",
            PuClass::Pva => "pva",
            PuClass::Vic => "vic",
        }
    }
}

/// Shared-resource classes the slowdown models are keyed by (§2.2, Fig. 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ResourceKind {
    L2Cache,
    L3Cache,
    /// last-level cache shared between CPU and GPU on Jetson-class SoCs
    Llc,
    /// vision-cluster scratchpad shared by DLA/PVA
    Sram,
    /// system DRAM (LPDDR on edges, DDR on servers)
    SysDram,
    /// memory controller / fabric
    MemController,
    /// a network link
    NetLink,
}

impl ResourceKind {
    pub fn name(&self) -> &'static str {
        match self {
            ResourceKind::L2Cache => "l2",
            ResourceKind::L3Cache => "l3",
            ResourceKind::Llc => "llc",
            ResourceKind::Sram => "sram",
            ResourceKind::SysDram => "dram",
            ResourceKind::MemController => "memctl",
            ResourceKind::NetLink => "netlink",
        }
    }
}

/// Role of a group node in the hierarchy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupRole {
    /// the whole continuum
    Root,
    /// a virtual grouping (edge cluster, server cluster)
    Cluster,
    /// a physical node: an edge device or a server
    Device,
    /// an intra-device complex (CPU cluster, vision cluster)
    Complex,
}

#[derive(Debug, Clone, PartialEq)]
pub enum NodeKind {
    /// a processing unit tasks can be mapped to (`Predictable` in the paper)
    Compute { class: PuClass },
    /// cache / scratchpad / DRAM with a service capacity used by the
    /// contention models (GB/s of demand it absorbs before saturating)
    Storage {
        resource: ResourceKind,
        capacity_gbps: f64,
    },
    /// memory controller, network switch, ...
    Controller { resource: ResourceKind },
    /// a component whose internals are unknown to this side of the system
    Abstract,
    /// sub-graph boundary
    Group { role: GroupRole },
}

/// Interconnect classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    OnChip,
    MemBus,
    PcIe,
    /// local network (same router / WLAN-like)
    Lan,
    /// wide-area hop (edge <-> cloud)
    Wan,
    /// unknown infrastructure between abstract components
    AbstractLink,
}

#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: NodeKind,
    /// abstraction layer, 1 = top (most abstract); grows with detail (Fig. 4a)
    pub layer: u8,
    /// containment: the group this node lives in
    pub parent: Option<NodeId>,
    /// cross-layer link: the more abstract node this one refines
    pub refines: Option<NodeId>,
    /// device model tag on Device groups ("orin_agx", "server1", ...)
    pub model: Option<String>,
}

#[derive(Debug, Clone)]
pub struct Edge {
    pub id: EdgeId,
    pub a: NodeId,
    pub b: NodeId,
    pub kind: LinkKind,
    pub bandwidth_gbps: f64,
    pub latency_s: f64,
}

/// The multi-layer hardware graph.
#[derive(Debug, Clone, Default)]
pub struct HwGraph {
    pub(crate) nodes: Vec<Node>,
    pub(crate) edges: Vec<Edge>,
    /// adjacency: node -> [(neighbor, edge)]
    pub(crate) adj: Vec<Vec<(NodeId, EdgeId)>>,
    /// containment children, derived from `parent`
    pub(crate) children: Vec<Vec<NodeId>>,
    /// name -> id (names are unique; enforced on insert)
    pub(crate) by_name: BTreeMap<String, NodeId>,
    /// structural epoch: bumped by every topology mutation (`add_node`,
    /// `add_edge`, `attach`), so derived caches ([`crate::netsim::RouteTable`],
    /// [`crate::slowdown::CachedSlowdown`]) can validate themselves with a
    /// single integer compare instead of re-deriving anything. Monotonic —
    /// never reset, survives `Clone`.
    pub(crate) epoch: u64,
}

impl HwGraph {
    pub fn new() -> Self {
        Self::default()
    }

    // ---- structure ---------------------------------------------------

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0 as usize]
    }

    pub fn edge(&self, id: EdgeId) -> &Edge {
        &self.edges[id.0 as usize]
    }

    pub fn edge_mut(&mut self, id: EdgeId) -> &mut Edge {
        &mut self.edges[id.0 as usize]
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn edge_count(&self) -> usize {
        self.edges.len()
    }

    pub fn nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter()
    }

    pub fn by_name(&self, name: &str) -> Option<NodeId> {
        self.by_name.get(name).copied()
    }

    pub fn neighbors(&self, id: NodeId) -> &[(NodeId, EdgeId)] {
        &self.adj[id.0 as usize]
    }

    /// The structural epoch: strictly increases with every topology
    /// mutation. Two graphs (or a graph and a cache built from it) with the
    /// same epoch along one mutation history have identical structure.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn children(&self, id: NodeId) -> &[NodeId] {
        &self.children[id.0 as usize]
    }

    // ---- mutation ------------------------------------------------------

    pub fn add_node(
        &mut self,
        name: &str,
        kind: NodeKind,
        layer: u8,
        parent: Option<NodeId>,
    ) -> NodeId {
        assert!(
            !self.by_name.contains_key(name),
            "duplicate node name `{name}`"
        );
        self.epoch += 1;
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            kind,
            layer,
            parent,
            refines: None,
            model: None,
        });
        self.adj.push(Vec::new());
        self.children.push(Vec::new());
        self.by_name.insert(name.to_string(), id);
        if let Some(p) = parent {
            self.children[p.0 as usize].push(id);
        }
        id
    }

    pub fn add_edge(
        &mut self,
        a: NodeId,
        b: NodeId,
        kind: LinkKind,
        bandwidth_gbps: f64,
        latency_s: f64,
    ) -> EdgeId {
        self.epoch += 1;
        let id = EdgeId(self.edges.len() as u32);
        self.edges.push(Edge {
            id,
            a,
            b,
            kind,
            bandwidth_gbps,
            latency_s,
        });
        self.adj[a.0 as usize].push((b, id));
        self.adj[b.0 as usize].push((a, id));
        id
    }

    pub fn set_refines(&mut self, detailed: NodeId, abstract_node: NodeId) {
        self.nodes[detailed.0 as usize].refines = Some(abstract_node);
    }

    pub fn set_model(&mut self, id: NodeId, model: &str) {
        self.nodes[id.0 as usize].model = Some(model.to_string());
    }

    /// Record a structural change that adds no nodes or edges: a device
    /// re-registering after a membership failure
    /// ([`presets::Decs::reactivate`]). Every id and link is unchanged,
    /// but the serving membership moved, so epoch-keyed caches must
    /// re-validate (the route tables treat a re-registration exactly like
    /// a join: the owning domain delta-updates, foreign slices adopt the
    /// epoch without rebuilding).
    pub fn bump_epoch(&mut self) {
        self.epoch += 1;
    }

    /// Re-parent `child` under `group` (dynamic adaptability: a new edge
    /// device joining an edge cluster, §5.4.2).
    pub fn attach(&mut self, child: NodeId, group: NodeId) {
        self.epoch += 1;
        if let Some(old) = self.nodes[child.0 as usize].parent {
            self.children[old.0 as usize].retain(|&c| c != child);
        }
        self.nodes[child.0 as usize].parent = Some(group);
        self.children[group.0 as usize].push(child);
    }

    // ---- queries -------------------------------------------------------

    pub fn is_pu(&self, id: NodeId) -> bool {
        matches!(self.node(id).kind, NodeKind::Compute { .. })
    }

    pub fn pu_class(&self, id: NodeId) -> Option<PuClass> {
        match self.node(id).kind {
            NodeKind::Compute { class } => Some(class),
            _ => None,
        }
    }

    /// All PUs contained (transitively) under a group.
    pub fn pus_in(&self, group: NodeId) -> Vec<NodeId> {
        let mut out = Vec::new();
        let mut stack = vec![group];
        while let Some(n) = stack.pop() {
            if self.is_pu(n) {
                out.push(n);
            }
            stack.extend(self.children(n).iter().copied());
        }
        out.sort();
        out
    }

    /// The Device group that (transitively) contains `id`.
    pub fn device_of(&self, id: NodeId) -> Option<NodeId> {
        let mut cur = Some(id);
        while let Some(n) = cur {
            if matches!(
                self.node(n).kind,
                NodeKind::Group {
                    role: GroupRole::Device
                }
            ) {
                return Some(n);
            }
            cur = self.node(n).parent;
        }
        None
    }

    /// The model tag of the device containing `id`.
    pub fn device_model_of(&self, id: NodeId) -> Option<&str> {
        self.device_of(id)
            .and_then(|d| self.node(d).model.as_deref())
    }

    /// Groups with a given role.
    pub fn groups(&self, role: GroupRole) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.kind, NodeKind::Group { role: r } if r == role))
            .map(|n| n.id)
            .collect()
    }

    /// Resource kind of a storage/controller node.
    pub fn resource_kind(&self, id: NodeId) -> Option<ResourceKind> {
        match self.node(id).kind {
            NodeKind::Storage { resource, .. } => Some(resource),
            NodeKind::Controller { resource } => Some(resource),
            _ => None,
        }
    }
}
