//! Path algorithms over the HW-Graph: Dijkstra SSSP, `compute_path`
//! (getComputePath() of §3.3) and shared-resource discovery.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};

use super::{HwGraph, NodeId, NodeKind, ResourceKind};

/// Process-wide count of *whole-graph* Dijkstra (SSSP) runs, across all
/// graphs and threads — the cost of route resolution (`Network::route`,
/// `path_between`, the `RouteTable` build). Device-local filtered SSSPs
/// (compute-path discovery inside one SoC) are not counted: they are tiny,
/// and both cached and uncached runs pay them identically at oracle
/// construction. The route cache exists to keep this counter flat in the
/// simulation hot path; `perf_hotpath`/`fig17_churn` report deltas of it,
/// and the cache-coherence tests assert on it. Diagnostic only — relaxed
/// ordering, never reset.
static SSSP_RUNS: AtomicU64 = AtomicU64::new(0);

/// Total whole-graph Dijkstra invocations so far in this process — route
/// resolution cost only; device-local compute-path SSSPs are not counted.
/// Diagnostic: relaxed ordering, never reset.
pub fn sssp_invocations() -> u64 {
    SSSP_RUNS.load(AtomicOrdering::Relaxed)
}

#[derive(PartialEq)]
struct HeapItem {
    dist: f64,
    node: NodeId,
}

impl Eq for HeapItem {}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on distance
        other
            .dist
            .partial_cmp(&self.dist)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.node.0.cmp(&self.node.0))
    }
}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl HwGraph {
    /// Single-source shortest path (by link latency, ties by hops) from
    /// `src` to every reachable node. Returns `(dist, prev)` arrays.
    pub fn sssp(&self, src: NodeId) -> (Vec<f64>, Vec<Option<NodeId>>) {
        SSSP_RUNS.fetch_add(1, AtomicOrdering::Relaxed);
        self.sssp_filtered(src, |_| true)
    }

    /// [`HwGraph::sssp`] restricted to nodes passing `allow` (the source is
    /// always expanded).
    fn sssp_filtered(
        &self,
        src: NodeId,
        allow: impl Fn(NodeId) -> bool,
    ) -> (Vec<f64>, Vec<Option<NodeId>>) {
        let n = self.node_count();
        let mut dist = vec![f64::INFINITY; n];
        let mut prev: Vec<Option<NodeId>> = vec![None; n];
        let mut heap = BinaryHeap::new();
        dist[src.0 as usize] = 0.0;
        heap.push(HeapItem {
            dist: 0.0,
            node: src,
        });
        while let Some(HeapItem { dist: d, node }) = heap.pop() {
            if d > dist[node.0 as usize] {
                continue;
            }
            for &(next, eid) in self.neighbors(node) {
                if !allow(next) {
                    continue;
                }
                let e = self.edge(eid);
                // epsilon keeps zero-latency on-chip hops strictly ordered
                let nd = d + e.latency_s + 1e-12;
                if nd < dist[next.0 as usize] {
                    dist[next.0 as usize] = nd;
                    prev[next.0 as usize] = Some(node);
                    heap.push(HeapItem {
                        dist: nd,
                        node: next,
                    });
                }
            }
        }
        (dist, prev)
    }

    /// Shortest path between two nodes as a node list (inclusive), or None
    /// if unreachable.
    pub fn path_between(&self, src: NodeId, dst: NodeId) -> Option<Vec<NodeId>> {
        let (dist, prev) = self.sssp(src);
        self.path_from_sssp(&dist, &prev, src, dst)
    }

    /// Reconstruct the `src`→`dst` path from one `sssp(src)` result — so a
    /// caller resolving many destinations from the same source (e.g. the
    /// [`crate::netsim::RouteTable`] build) pays one Dijkstra, not one per
    /// destination, and still gets exactly the paths [`HwGraph::path_between`]
    /// would return.
    pub fn path_from_sssp(
        &self,
        dist: &[f64],
        prev: &[Option<NodeId>],
        src: NodeId,
        dst: NodeId,
    ) -> Option<Vec<NodeId>> {
        if dist[dst.0 as usize].is_infinite() {
            return None;
        }
        let mut path = vec![dst];
        let mut cur = dst;
        while let Some(p) = prev[cur.0 as usize] {
            path.push(p);
            cur = p;
        }
        path.reverse();
        debug_assert_eq!(path[0], src);
        Some(path)
    }

    /// getComputePath(): the storage/controller resources a PU relies on as
    /// it operates — the shortest path(s) from the PU to the system DRAM it
    /// is backed by, i.e. the route its memory traffic takes through caches,
    /// scratchpads and controllers. This is what profiling caches in the
    /// TASK struct per §3.3; here it's cheap enough to recompute.
    pub fn compute_path(&self, pu: NodeId) -> Vec<NodeId> {
        self.memory_chain(pu, false)
    }

    /// [`HwGraph::compute_path`] restricted to the PU's own device
    /// sub-graph. Memory traffic never profitably leaves the device (the
    /// cheapest network hop costs ~1e-4 s against ~1e-8 s on-chip links),
    /// so the result is identical — at device-local cost, which keeps the
    /// eager slowdown-cache construction cheap on fleet-scale graphs.
    pub fn compute_path_local(&self, pu: NodeId) -> Vec<NodeId> {
        self.memory_chain(pu, true)
    }

    /// Shared implementation of the compute-path variants: SSSP from the
    /// PU (optionally restricted to its device), then walk back from every
    /// in-device system DRAM collecting the storage/controller hops the
    /// memory traffic crosses.
    fn memory_chain(&self, pu: NodeId, device_only: bool) -> Vec<NodeId> {
        let device = match self.device_of(pu) {
            Some(d) => d,
            None => return vec![pu],
        };
        let (dist, prev) = if device_only {
            self.sssp_filtered(pu, |n| self.device_of(n) == Some(device))
        } else {
            self.sssp(pu)
        };
        let mut out = vec![pu];
        for n in self.nodes() {
            let in_device = self.device_of(n.id) == Some(device);
            let is_dram = matches!(
                n.kind,
                NodeKind::Storage {
                    resource: ResourceKind::SysDram,
                    ..
                }
            );
            if in_device && is_dram && dist[n.id.0 as usize].is_finite() {
                // walk the memory-access path back, collecting the
                // storage/controller hops it crosses
                let mut cur = n.id;
                while cur != pu {
                    let is_mem = matches!(
                        self.node(cur).kind,
                        NodeKind::Storage { .. } | NodeKind::Controller { .. }
                    );
                    if is_mem && !out.contains(&cur) {
                        out.push(cur);
                    }
                    match prev[cur.0 as usize] {
                        Some(p) => cur = p,
                        None => break,
                    }
                }
            }
        }
        out.sort();
        out.dedup();
        out
    }

    /// The shared storage/controller resources of two PUs: the intersection
    /// of their compute paths, restricted to memory-system nodes. In the
    /// Fig. 4a example this uncovers {SRAM, LPDDR4x} for (DLA, PVA).
    pub fn shared_resources(&self, pu_a: NodeId, pu_b: NodeId) -> Vec<NodeId> {
        if pu_a == pu_b {
            return vec![pu_a];
        }
        let pa = self.compute_path(pu_a);
        let pb = self.compute_path(pu_b);
        pa.into_iter()
            .filter(|n| pb.contains(n))
            .filter(|&n| self.resource_kind(n).is_some())
            .collect()
    }

    /// Shared resource *kinds* of two PUs (what the slowdown registry keys on).
    pub fn shared_resource_kinds(&self, pu_a: NodeId, pu_b: NodeId) -> Vec<ResourceKind> {
        let mut kinds: Vec<ResourceKind> = self
            .shared_resources(pu_a, pu_b)
            .into_iter()
            .filter_map(|n| self.resource_kind(n))
            .collect();
        kinds.sort();
        kinds.dedup();
        kinds
    }
}

#[cfg(test)]
mod tests {
    use super::super::{GroupRole, LinkKind, NodeKind, PuClass};
    use super::*;

    /// tiny SoC: two cores behind one L2, a GPU, all meeting at DRAM
    fn tiny() -> (HwGraph, NodeId, NodeId, NodeId) {
        let mut g = HwGraph::new();
        let dev = g.add_node(
            "dev",
            NodeKind::Group {
                role: GroupRole::Device,
            },
            1,
            None,
        );
        let c0 = g.add_node(
            "c0",
            NodeKind::Compute {
                class: PuClass::CpuCore,
            },
            2,
            Some(dev),
        );
        let c1 = g.add_node(
            "c1",
            NodeKind::Compute {
                class: PuClass::CpuCore,
            },
            2,
            Some(dev),
        );
        let gpu = g.add_node(
            "gpu",
            NodeKind::Compute {
                class: PuClass::Gpu,
            },
            2,
            Some(dev),
        );
        let l2 = g.add_node(
            "l2",
            NodeKind::Storage {
                resource: ResourceKind::L2Cache,
                capacity_gbps: 100.0,
            },
            2,
            Some(dev),
        );
        let dram = g.add_node(
            "dram",
            NodeKind::Storage {
                resource: ResourceKind::SysDram,
                capacity_gbps: 60.0,
            },
            2,
            Some(dev),
        );
        g.add_edge(c0, l2, LinkKind::OnChip, 200.0, 1e-9);
        g.add_edge(c1, l2, LinkKind::OnChip, 200.0, 1e-9);
        g.add_edge(l2, dram, LinkKind::MemBus, 60.0, 1e-8);
        g.add_edge(gpu, dram, LinkKind::MemBus, 60.0, 1e-8);
        (g, c0, c1, gpu)
    }

    #[test]
    fn compute_path_collects_memory_chain() {
        let (g, c0, _, gpu) = tiny();
        let p = g.compute_path(c0);
        let names: Vec<&str> = p.iter().map(|&n| g.node(n).name.as_str()).collect();
        assert!(names.contains(&"l2") && names.contains(&"dram"));
        let pg = g.compute_path(gpu);
        let names: Vec<&str> = pg.iter().map(|&n| g.node(n).name.as_str()).collect();
        assert!(names.contains(&"dram") && !names.contains(&"l2"));
    }

    #[test]
    fn shared_resources_cores_share_l2_and_dram() {
        let (g, c0, c1, gpu) = tiny();
        let kinds = g.shared_resource_kinds(c0, c1);
        assert!(kinds.contains(&ResourceKind::L2Cache));
        assert!(kinds.contains(&ResourceKind::SysDram));
        let kinds = g.shared_resource_kinds(c0, gpu);
        assert_eq!(kinds, vec![ResourceKind::SysDram]);
    }

    #[test]
    fn path_between_works_and_respects_latency() {
        let (g, c0, c1, _) = tiny();
        let p = g.path_between(c0, c1).unwrap();
        assert_eq!(p.len(), 3); // c0 -> l2 -> c1
        assert!(g.path_between(c0, c0).unwrap().len() == 1);
    }

    #[test]
    fn local_compute_path_matches_global() {
        use crate::hwgraph::presets::{Decs, DecsSpec};
        let decs = Decs::build(&DecsSpec::paper_vr());
        let g = &decs.graph;
        for &dev in decs.edge_devices.iter().chain(decs.servers.iter()) {
            for pu in g.pus_in(dev) {
                assert_eq!(
                    g.compute_path_local(pu),
                    g.compute_path(pu),
                    "compute paths diverge for {}",
                    g.node(pu).name
                );
            }
        }
    }

    #[test]
    fn same_pu_shares_itself() {
        let (g, c0, _, _) = tiny();
        assert_eq!(g.shared_resources(c0, c0), vec![c0]);
    }

    #[test]
    fn unreachable_returns_none() {
        let mut g = HwGraph::new();
        let a = g.add_node(
            "a",
            NodeKind::Compute {
                class: PuClass::CpuCore,
            },
            1,
            None,
        );
        let b = g.add_node(
            "b",
            NodeKind::Compute {
                class: PuClass::CpuCore,
            },
            1,
            None,
        );
        assert!(g.path_between(a, b).is_none());
    }
}
