//! Calibration tables: standalone latencies (Fig. 9), per-device scaling,
//! memory intensities, contention coefficients (Fig. 2), multi-tenancy
//! curves, and PU power draws.
//!
//! The paper reports Fig. 9 as a plot without a numeric table; values here
//! are chosen to match every relationship the text states:
//! * edge GPUs cannot render a frame within the FPS budget; server GPUs can
//!   (rendering is "predominantly processed by servers", §4.1);
//! * reproject: edge CPU standalone beats VIC, but VIC has private storage
//!   (§5.3.1) so it wins under memory contention;
//! * Orin AGX > Xavier AGX > Xavier NX > Orin Nano in capability;
//! * server-3 (integrated graphics) is markedly weaker than 1 and 2;
//! * KNN is the heaviest mining task and its Xavier-NX time is the strong-
//!   scaling limit (§5.5.3).

use crate::hwgraph::{presets, PuClass, ResourceKind};
use crate::task::TaskKind;

/// Device-level latency multiplier relative to Orin AGX (edges) or the
/// absolute server factors.
pub fn device_factor(model: &str) -> Option<f64> {
    Some(match model {
        presets::ORIN_AGX => 1.0,
        presets::XAVIER_AGX => 1.4,
        presets::XAVIER_NX => 1.9,
        presets::ORIN_NANO => 2.3,
        // Server factors put the three shared servers at the edge of
        // saturation under the 5-headset VR load (§5.3.1: servers are the
        // bottleneck for three of the five devices) — fast enough to render
        // in-budget standalone, slow enough that multi-tenancy decisions
        // decide QoS.
        presets::SERVER1 => 0.45,
        presets::SERVER2 => 0.40,
        presets::SERVER3 => 0.60,
        _ => return None,
    })
}

fn is_server(model: &str) -> bool {
    presets::SERVER_MODELS.contains(&model)
}

/// Base standalone latency (seconds) of a unit-scale task on an *Orin AGX*
/// PU of the given class; `device_factor` scales it to other devices.
fn base_s(pu: PuClass, kind: TaskKind) -> Option<f64> {
    use PuClass::*;
    use TaskKind::*;
    let ms = match (kind, pu) {
        // --- VR pipeline ---
        (Capture, CpuCore) => 2.0,
        (PosePredict, CpuCore) => 3.0,
        (PosePredict, Gpu) => 2.5,
        (Render, Gpu) => 45.0,
        (Encode, CpuCore) => 15.0,
        (Encode, Gpu) => 8.0,
        (Encode, Vic) => 5.0,
        (Decode, CpuCore) => 14.0,
        (Decode, Gpu) => 7.0,
        (Decode, Vic) => 5.0,
        (Reproject, CpuCore) => 4.0,
        (Reproject, Gpu) => 6.0,
        (Reproject, Vic) => 5.0,
        (Display, CpuCore) => 2.0,
        // --- mining ---
        (SensorRead, CpuCore) => 1.0,
        (Svm, CpuCore) => 7.0,
        (Svm, Gpu) => 3.0,
        (Knn, CpuCore) => 11.0,
        (Knn, Gpu) => 5.0,
        (Mlp, CpuCore) => 4.5,
        (Mlp, Gpu) => 1.8,
        // --- microbenchmarks ---
        (MatMul, CpuCore) => 10.0,
        (MatMul, Gpu) => 2.0,
        (MatMul, Dla) => 4.0,
        (MatMul, Pva) => 6.0,
        (DnnInfer, Gpu) => 8.0,
        (DnnInfer, Dla) => 14.0,
        (DnnInfer, CpuCore) => 40.0,
        _ => return None,
    };
    Some(ms * 1e-3)
}

/// Standalone latency of a unit-scale task on (device model, PU class).
pub fn standalone_s(model: &str, pu: PuClass, kind: TaskKind) -> Option<f64> {
    // servers have no VIC/DLA/PVA in our presets; the graph guarantees the
    // PU exists before this is asked, but keep the table honest anyway.
    if is_server(model) && matches!(pu, PuClass::Vic | PuClass::Dla | PuClass::Pva) {
        return None;
    }
    Some(base_s(pu, kind)? * device_factor(model)?)
}

/// Rough PU power draws (W) for the Joules unit.
pub fn power_w(model: &str, pu: PuClass) -> f64 {
    let base = match pu {
        PuClass::CpuCore => 3.0,
        PuClass::Gpu => 15.0,
        PuClass::Dla => 4.0,
        PuClass::Pva => 3.0,
        PuClass::Vic => 2.5,
    };
    if is_server(model) {
        base * 8.0
    } else {
        base
    }
}

// ---------------------------------------------------------------------------
// shared-resource slowdown calibration (Fig. 2)
// ---------------------------------------------------------------------------

/// Pairwise contention factor at full memory intensity for two co-runners
/// whose *nearest* shared resource is `kind`: the Fig. 2 measurements on
/// Orin AGX, inverted into slowdown multipliers.
pub fn contention_factor(kind: ResourceKind) -> f64 {
    match kind {
        ResourceKind::L2Cache => 1.0 / 0.91,      // same-cluster cores
        ResourceKind::L3Cache => 1.0 / 0.87,      // cross-cluster cores
        ResourceKind::Llc => 1.0 / 0.89,          // CPU + GPU via the 4MB LLC
        ResourceKind::Sram => 1.0 / 0.71,         // DLA + PVA vision SRAM
        ResourceKind::SysDram => 1.0 / 0.68,      // GPU + DLA via DRAM
        ResourceKind::MemController => 1.0 / 0.80,
        ResourceKind::NetLink => 1.0, // handled by the flow model, not here
    }
}

/// Memory intensity in [0, 1]: how hard a task drives the memory system
/// relative to the dense-MM microbenchmark (= 1.0). Scales the pairwise
/// contention factor (PCCS-style processor-centric demand abstraction).
pub fn memory_intensity(kind: TaskKind, pu: PuClass) -> f64 {
    use TaskKind::*;
    let base = match kind {
        MatMul | DnnInfer => 1.0,
        Render => 0.9,
        Encode | Decode => 0.7,
        Reproject => 0.6,
        Knn => 0.8,
        Svm => 0.6,
        Mlp => 0.5,
        PosePredict => 0.3,
        Capture | Display | SensorRead => 0.15,
    };
    // VIC's private storage keeps its traffic off the shared hierarchy
    if pu == PuClass::Vic {
        base * 0.25
    } else {
        base
    }
}

/// Contention *sensitivity* in [0, ~4]: how much a task suffers per unit of
/// co-runner pressure. Decoupled from `memory_intensity` (how much pressure
/// the task *generates*): the pairwise slowdown a target experiences is
/// `1 + (factor-1) * sensitivity(target) * intensity(co)`.
///
/// The asymmetries encode the §5.3.1 observations: pipeline stages whose
/// working sets are LLC-resident on the CPU (reproject/codec/pose-RNN)
/// suffer disproportionately when the GPU floods the shared LLC, while the
/// VIC's private data storage makes it nearly immune.
pub fn contention_sensitivity(kind: TaskKind, pu: PuClass) -> f64 {
    use TaskKind::*;
    if pu == PuClass::Vic {
        return 0.2;
    }
    match (kind, pu) {
        (Reproject | Encode | Decode, PuClass::CpuCore) => 3.5,
        (PosePredict, PuClass::CpuCore) => 2.5,
        (Svm | Knn | Mlp, PuClass::CpuCore) => 1.6,
        _ => memory_intensity(kind, pu),
    }
}

// ---------------------------------------------------------------------------
// multi-tenancy calibration (§2.2 and the server-GPU estimates of §5.1)
// ---------------------------------------------------------------------------

/// Relative speed of each tenant when `k` tasks time-share one PU.
/// Edge GPU: Fig. 2 measures 0.66x for k=2 -> mu = 0.515 in
/// `1 / (1 + mu (k-1))`. Server GPUs are better at co-tenancy (djay-style
/// profiling, §5.1). CPU cores degrade as pure timeslicing, and beyond two
/// tenants accelerators fall back to timeslicing on top of the measured
/// 2-tenant interference (kernels serialize; interference does not keep
/// compounding).
pub fn multitenancy_rel_speed(model: &str, pu: PuClass, k: usize) -> f64 {
    if k <= 1 {
        return 1.0;
    }
    let kf = k as f64;
    let mu = match (is_server(model), pu) {
        (_, PuClass::CpuCore) => return 1.0 / kf, // timeslice
        (false, PuClass::Gpu) => 0.515,
        (true, PuClass::Gpu) => 0.25,
        (_, PuClass::Dla) => 0.6,
        (_, PuClass::Pva) => 0.6,
        (_, PuClass::Vic) => 0.4,
    };
    let pair = 1.0 / (1.0 + mu); // measured 2-tenant relative speed
    if k == 2 {
        pair
    } else {
        pair * 2.0 / kf // timeslice beyond two tenants
    }
}

/// Upper bound on the composed memory-contention multiplier: once the
/// shared level saturates, adding co-runners queues requests instead of
/// compounding interference (PCCS observes the same plateau).
pub const MEM_CONTENTION_CAP: f64 = 4.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_targets_reproduced_exactly() {
        // the five measured relative performances on Orin AGX
        assert!((1.0 / contention_factor(ResourceKind::L2Cache) - 0.91).abs() < 1e-9);
        assert!((1.0 / contention_factor(ResourceKind::L3Cache) - 0.87).abs() < 1e-9);
        assert!((1.0 / contention_factor(ResourceKind::Llc) - 0.89).abs() < 1e-9);
        assert!((1.0 / contention_factor(ResourceKind::SysDram) - 0.68).abs() < 1e-9);
        assert!(
            (multitenancy_rel_speed(presets::ORIN_AGX, PuClass::Gpu, 2) - 0.66).abs() < 0.005
        );
    }

    #[test]
    fn device_order() {
        let f = |m| device_factor(m).unwrap();
        assert!(f(presets::ORIN_AGX) < f(presets::XAVIER_AGX));
        assert!(f(presets::XAVIER_AGX) < f(presets::XAVIER_NX));
        assert!(f(presets::XAVIER_NX) < f(presets::ORIN_NANO));
        assert!(f(presets::SERVER2) < f(presets::SERVER1));
        assert!(f(presets::SERVER1) < f(presets::SERVER3));
    }

    #[test]
    fn knn_is_heaviest_mining_task() {
        for pu in [PuClass::CpuCore, PuClass::Gpu] {
            let knn = base_s(pu, TaskKind::Knn).unwrap();
            assert!(knn > base_s(pu, TaskKind::Svm).unwrap());
            assert!(knn > base_s(pu, TaskKind::Mlp).unwrap());
        }
    }

    #[test]
    fn multitenancy_monotone_decreasing() {
        for k in 1..8 {
            let a = multitenancy_rel_speed(presets::SERVER1, PuClass::Gpu, k);
            let b = multitenancy_rel_speed(presets::SERVER1, PuClass::Gpu, k + 1);
            assert!(b < a || (k == 0));
        }
        // servers tolerate co-tenancy better than edges
        assert!(
            multitenancy_rel_speed(presets::SERVER1, PuClass::Gpu, 2)
                > multitenancy_rel_speed(presets::ORIN_AGX, PuClass::Gpu, 2)
        );
    }

    #[test]
    fn vic_intensity_discounted() {
        assert!(
            memory_intensity(TaskKind::Reproject, PuClass::Vic)
                < memory_intensity(TaskKind::Reproject, PuClass::CpuCore)
        );
    }

    #[test]
    fn servers_lack_accelerator_entries() {
        assert!(standalone_s(presets::SERVER1, PuClass::Vic, TaskKind::Reproject).is_none());
        assert!(standalone_s(presets::ORIN_AGX, PuClass::Vic, TaskKind::Reproject).is_some());
    }
}
