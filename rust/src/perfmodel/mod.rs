//! The modular performance-model interface (`Predictable` in §3.3).
//!
//! `predict()` takes the TASK (kind + size scale) and a UNIT and returns the
//! *standalone* cost of running it on a PU — slowdown is deliberately
//! decoupled and lives in [`crate::slowdown`] (§3.4 "Slowdown calculation").
//! The default implementation is a profile table calibrated to the paper's
//! Fig. 9 standalone latencies; a host-measured model (built from real PJRT
//! executions of the AOT artifacts) can overlay it for the e2e examples.

pub mod calibration;

use std::collections::BTreeMap;

use crate::hwgraph::PuClass;
use crate::task::TaskSpec;

/// What `predict()` should return (the paper's UNIT parameter).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    Seconds,
    /// energy; modeled as time x PU-class power draw (used by reports only)
    Joules,
}

/// The `Predictable` interface: standalone cost of a task on a PU of a
/// given device model. Returns `None` when the PU class cannot run the task
/// (not in its candidate set) or the model has no entry.
pub trait PerfModel: Send + Sync {
    fn predict(&self, task: &TaskSpec, device_model: &str, pu: PuClass, unit: Unit) -> Option<f64>;
}

/// Profile-table model calibrated to Fig. 9 (empirical profiling is what the
/// paper uses in its experiments, §3.3).
#[derive(Debug, Clone, Default)]
pub struct ProfileModel {
    /// optional overrides: (device_model, pu, task-kind-name) -> seconds
    overrides: BTreeMap<(String, PuClass, &'static str), f64>,
}

impl ProfileModel {
    pub fn new() -> Self {
        Self::default()
    }

    /// Override one profile entry (used by the host-measured e2e path and
    /// by ablations).
    pub fn set(&mut self, device_model: &str, pu: PuClass, task_name: &'static str, secs: f64) {
        self.overrides
            .insert((device_model.to_string(), pu, task_name), secs);
    }
}

impl PerfModel for ProfileModel {
    fn predict(&self, task: &TaskSpec, device_model: &str, pu: PuClass, unit: Unit) -> Option<f64> {
        if !task.kind.allowed_pus().contains(&pu) {
            return None;
        }
        let base = self
            .overrides
            .get(&(device_model.to_string(), pu, task.kind.name()))
            .copied()
            .or_else(|| calibration::standalone_s(device_model, pu, task.kind))?;
        // linear size scaling relative to the profiled unit workload
        let secs = base * task.size_scale.max(0.0);
        match unit {
            Unit::Seconds => Some(secs),
            Unit::Joules => Some(secs * calibration::power_w(device_model, pu)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwgraph::presets::*;
    use crate::task::{TaskKind, TaskSpec};

    fn t(kind: TaskKind) -> TaskSpec {
        TaskSpec::new(kind)
    }

    #[test]
    fn render_only_on_gpu() {
        let m = ProfileModel::new();
        assert!(m
            .predict(&t(TaskKind::Render), ORIN_AGX, PuClass::Gpu, Unit::Seconds)
            .is_some());
        assert!(m
            .predict(
                &t(TaskKind::Render),
                ORIN_AGX,
                PuClass::CpuCore,
                Unit::Seconds
            )
            .is_none());
    }

    #[test]
    fn size_scale_is_linear() {
        let m = ProfileModel::new();
        let one = m
            .predict(&t(TaskKind::Svm), ORIN_NANO, PuClass::Gpu, Unit::Seconds)
            .unwrap();
        let five = m
            .predict(
                &t(TaskKind::Svm).scale(5.0),
                ORIN_NANO,
                PuClass::Gpu,
                Unit::Seconds,
            )
            .unwrap();
        assert!((five / one - 5.0).abs() < 1e-9);
    }

    #[test]
    fn server_gpus_beat_edge_gpus_on_render() {
        let m = ProfileModel::new();
        let edge = m
            .predict(&t(TaskKind::Render), ORIN_AGX, PuClass::Gpu, Unit::Seconds)
            .unwrap();
        let srv = m
            .predict(&t(TaskKind::Render), SERVER1, PuClass::Gpu, Unit::Seconds)
            .unwrap();
        assert!(srv < edge, "server render {srv} should beat edge {edge}");
    }

    #[test]
    fn edge_render_misses_its_frame_budget() {
        // the premise of the whole VR scenario: edges cannot render in time
        let m = ProfileModel::new();
        for model in EDGE_MODELS {
            let r = m
                .predict(&t(TaskKind::Render), model, PuClass::Gpu, Unit::Seconds)
                .unwrap();
            let period = 1.0 / crate::task::workloads::target_fps(model);
            assert!(r > period, "{model}: render {r} fits {period}");
        }
    }

    #[test]
    fn overrides_take_precedence() {
        let mut m = ProfileModel::new();
        m.set(ORIN_AGX, PuClass::Gpu, "render", 0.001);
        let v = m
            .predict(&t(TaskKind::Render), ORIN_AGX, PuClass::Gpu, Unit::Seconds)
            .unwrap();
        assert_eq!(v, 0.001);
    }

    #[test]
    fn joules_scale_with_power() {
        let m = ProfileModel::new();
        let s = m
            .predict(&t(TaskKind::Mlp), ORIN_AGX, PuClass::Gpu, Unit::Seconds)
            .unwrap();
        let j = m
            .predict(&t(TaskKind::Mlp), ORIN_AGX, PuClass::Gpu, Unit::Joules)
            .unwrap();
        assert!(j > s); // GPU power > 1 W
    }

    #[test]
    fn reproject_cpu_beats_vic_standalone() {
        // §5.3.1: LaTS prefers the CPU because its *standalone* time is
        // better than the VIC's — the trap H-EYE avoids under contention.
        let m = ProfileModel::new();
        let cpu = m
            .predict(
                &t(TaskKind::Reproject),
                ORIN_AGX,
                PuClass::CpuCore,
                Unit::Seconds,
            )
            .unwrap();
        let vic = m
            .predict(&t(TaskKind::Reproject), ORIN_AGX, PuClass::Vic, Unit::Seconds)
            .unwrap();
        assert!(cpu < vic);
    }
}

// ---------------------------------------------------------------------------
// roofline model
// ---------------------------------------------------------------------------

/// Per-task compute/memory characteristics for the roofline model:
/// FLOPs and bytes moved per unit-scale instance.
fn task_flops_bytes(kind: crate::task::TaskKind) -> (f64, f64) {
    use crate::task::TaskKind::*;
    // derived from the L2 model shapes (see python/compile/model.py and
    // artifacts/manifest.json): render/encode/decode are 256x256 dense
    // mixes, the classifiers are (32, 64) batches
    match kind {
        Render => (67.1e6, 2.1e6),
        Encode | Decode => (67.1e6, 1.6e6),
        Reproject => (33.6e6, 1.3e6),
        PosePredict => (36.9e3, 120.0e3),
        Svm => (1.18e6, 180.0e3),
        Knn => (2.10e6, 300.0e3),
        Mlp => (1.08e6, 140.0e3),
        Capture | Display | SensorRead => (0.26e6, 260.0e3),
        MatMul => (33.6e6, 800.0e3),
        DnnInfer => (134.0e6, 4.0e6),
    }
}

/// Peak compute (GFLOP/s) and memory bandwidth (GB/s) per (device, PU).
fn pu_peaks(device_model: &str, pu: PuClass) -> Option<(f64, f64)> {
    let f = calibration::device_factor(device_model)?;
    // Orin-AGX-class reference peaks, scaled inversely with the device
    // latency factor (a faster device has proportionally higher peaks)
    let (gflops, gbs) = match pu {
        PuClass::CpuCore => (25.0, 20.0),
        PuClass::Gpu => (1000.0, 100.0),
        PuClass::Dla => (500.0, 60.0),
        PuClass::Pva => (100.0, 30.0),
        PuClass::Vic => (80.0, 40.0),
    };
    Some((gflops / f, gbs / f))
}

/// Roofline performance model (§3.3 lists it as one of the pluggable
/// `predict()` backends): latency = max(flops / peak_flops,
/// bytes / peak_bandwidth). Useful when no profile exists for a task; the
/// arithmetic-intensity crossover decides compute- vs memory-bound.
#[derive(Debug, Clone, Copy, Default)]
pub struct RooflineModel;

impl RooflineModel {
    /// Arithmetic intensity (FLOP/byte) of a task.
    pub fn intensity(kind: crate::task::TaskKind) -> f64 {
        let (f, b) = task_flops_bytes(kind);
        f / b
    }

    /// Machine balance (FLOP/byte) of a PU: the roofline ridge point.
    pub fn balance(device_model: &str, pu: PuClass) -> Option<f64> {
        let (gf, gb) = pu_peaks(device_model, pu)?;
        Some(gf / gb)
    }
}

impl PerfModel for RooflineModel {
    fn predict(&self, task: &TaskSpec, device_model: &str, pu: PuClass, unit: Unit) -> Option<f64> {
        if !task.kind.allowed_pus().contains(&pu) {
            return None;
        }
        let (flops, bytes) = task_flops_bytes(task.kind);
        let (gflops, gbs) = pu_peaks(device_model, pu)?;
        let scale = task.size_scale.max(0.0);
        let compute_s = flops * scale / (gflops * 1e9);
        let memory_s = bytes * scale / (gbs * 1e9);
        let secs = compute_s.max(memory_s);
        match unit {
            Unit::Seconds => Some(secs),
            Unit::Joules => Some(secs * calibration::power_w(device_model, pu)),
        }
    }
}

#[cfg(test)]
mod roofline_tests {
    use super::*;
    use crate::hwgraph::presets::*;
    use crate::task::{TaskKind, TaskSpec};

    #[test]
    fn roofline_respects_candidate_sets() {
        let m = RooflineModel;
        let t = TaskSpec::new(TaskKind::Render);
        assert!(m.predict(&t, ORIN_AGX, PuClass::Gpu, Unit::Seconds).is_some());
        assert!(m.predict(&t, ORIN_AGX, PuClass::CpuCore, Unit::Seconds).is_none());
    }

    #[test]
    fn roofline_orders_devices_like_profiles() {
        let m = RooflineModel;
        let t = TaskSpec::new(TaskKind::Render);
        let agx = m.predict(&t, ORIN_AGX, PuClass::Gpu, Unit::Seconds).unwrap();
        let nano = m.predict(&t, ORIN_NANO, PuClass::Gpu, Unit::Seconds).unwrap();
        let srv = m.predict(&t, SERVER2, PuClass::Gpu, Unit::Seconds).unwrap();
        assert!(srv < agx && agx < nano);
    }

    #[test]
    fn compute_bound_vs_memory_bound_split() {
        // render has high arithmetic intensity: compute-bound on the GPU;
        // capture is streaming: memory-bound everywhere
        assert!(
            RooflineModel::intensity(TaskKind::Render)
                > RooflineModel::balance(ORIN_AGX, PuClass::Gpu).unwrap()
        );
        assert!(
            RooflineModel::intensity(TaskKind::Capture)
                < RooflineModel::balance(ORIN_AGX, PuClass::CpuCore).unwrap()
        );
    }

    #[test]
    fn roofline_scales_linearly() {
        let m = RooflineModel;
        let one = m
            .predict(&TaskSpec::new(TaskKind::Knn), ORIN_AGX, PuClass::Gpu, Unit::Seconds)
            .unwrap();
        let three = m
            .predict(
                &TaskSpec::new(TaskKind::Knn).scale(3.0),
                ORIN_AGX,
                PuClass::Gpu,
                Unit::Seconds,
            )
            .unwrap();
        assert!((three / one - 3.0).abs() < 1e-9);
    }

    #[test]
    fn roofline_usable_as_traverser_backend() {
        // the modular-interface claim: swap the profile model for the
        // roofline model and predictions still work end to end
        use crate::hwgraph::presets::{Decs, DecsSpec};
        use crate::netsim::Network;
        use crate::slowdown::CachedSlowdown;
        use crate::task::workloads;
        use crate::traverser::Traverser;
        let decs = Decs::build(&DecsSpec::validation_pair());
        let slow = CachedSlowdown::new(&decs.graph);
        let net = Network::new();
        let roof = RooflineModel;
        let tr = Traverser::new(&decs.graph, &slow, &roof, &net);
        let cfg = workloads::mining_cfg(1.0);
        let pus = [
            decs.graph.by_name("edge0.cpu0").unwrap(),
            decs.graph.by_name("edge0.cpu1").unwrap(),
            decs.graph.by_name("edge0.gpu").unwrap(),
            decs.graph.by_name("edge0.gpu").unwrap(),
        ];
        let p = tr
            .predict(&cfg, &pus, decs.edge_devices[0], &[], 0.0)
            .expect("roofline-backed prediction");
        assert!(p.makespan > 0.0 && p.makespan.is_finite());
    }
}
