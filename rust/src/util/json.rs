//! Minimal JSON substrate (no offline `serde_json` in this image).
//!
//! Parses/serializes the artifact manifest written by `python/compile/aot.py`
//! and the experiment config / report files. Supports the full JSON grammar
//! minus exotic number forms; numbers are stored as `f64` which is exact for
//! every integer the manifest contains (< 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that reports *which* key was missing.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key `{key}`"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ----- constructors ---------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    // ----- parsing --------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ----- serialization -------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_indented(f, 0)
    }
}

impl Json {
    fn write_indented(&self, f: &mut fmt::Formatter<'_>, depth: usize) -> fmt::Result {
        let pad = "  ".repeat(depth + 1);
        let pad_close = "  ".repeat(depth);
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) if items.is_empty() => write!(f, "[]"),
            Json::Arr(items) => {
                writeln!(f, "[")?;
                for (i, it) in items.iter().enumerate() {
                    write!(f, "{pad}")?;
                    it.write_indented(f, depth + 1)?;
                    if i + 1 < items.len() {
                        write!(f, ",")?;
                    }
                    writeln!(f)?;
                }
                write!(f, "{pad_close}]")
            }
            Json::Obj(m) if m.is_empty() => write!(f, "{{}}"),
            Json::Obj(m) => {
                writeln!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    write!(f, "{pad}")?;
                    write_escaped(f, k)?;
                    write!(f, ": ")?;
                    v.write_indented(f, depth + 1)?;
                    if i + 1 < m.len() {
                        write!(f, ",")?;
                    }
                    writeln!(f)?;
                }
                write!(f, "{pad_close}}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
        assert_eq!(arr[2].get("b").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models": {"mlp": {"flops": 1310720, "inputs": [{"dtype": "float32", "shape": [32, 64]}]}}, "format": 1}"#;
        let v = Json::parse(src).unwrap();
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn manifest_shape_access() {
        let v = Json::parse(
            r#"{"models":{"m":{"inputs":[{"shape":[32,64],"dtype":"float32"}]}}}"#,
        )
        .unwrap();
        let shape: Vec<u64> = v
            .get("models")
            .unwrap()
            .get("m")
            .unwrap()
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_u64().unwrap())
            .collect();
        assert_eq!(shape, vec![32, 64]);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert_eq!(v.get("format").unwrap().as_u64().unwrap(), 1);
            assert!(v.get("models").unwrap().as_obj().unwrap().len() >= 9);
        }
    }
}
