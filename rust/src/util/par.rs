//! Zero-dependency data-parallel substrate (no offline `rayon` in this
//! image): a scoped worker pool over `std::thread::scope` with
//! *deterministic* results — every item's result lands in its input slot,
//! so callers reduce in input order and parallel runs are bit-identical to
//! serial ones regardless of thread scheduling.
//!
//! The mapping hot path (`Orchestrator::map_task`, the baselines'
//! candidate scoring) fans out over this module; `map_with` additionally
//! hands each worker its own scratch state so per-candidate evaluation
//! stays allocation-free.

use std::num::NonZeroUsize;

/// Resolve a parallelism knob to a worker count: `0` means auto-detect
/// (available cores), any other value is used as-is.
pub fn resolve(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(NonZeroUsize::get)
            .unwrap_or(1)
    } else {
        threads
    }
}

/// Minimum items each worker must have before a thread is spawned for it:
/// a scoped spawn costs ~10 µs, which tiny batches cannot amortize, so
/// small inputs automatically take the inline serial path (identical
/// results either way — only the wall clock changes).
pub const MIN_ITEMS_PER_WORKER: usize = 4;

/// Deterministic parallel map: applies `f` to every item and returns the
/// results in item order. With `threads <= 1` (or a single item) this runs
/// inline on the caller's thread with zero spawn cost.
pub fn map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    map_with(threads, items, || (), |_scratch, i, t| f(i, t))
}

/// Like [`map`], but each worker owns a scratch state built by `init`
/// (reusable buffers, so the per-item work can stay allocation-free).
/// Items are dealt to workers in strides; results are written back to
/// their input slots, so the output order — and therefore any in-order
/// reduction over it — is independent of which worker ran what.
pub fn map_with<T, R, S, I, F>(threads: usize, items: &[T], init: I, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let n = items.len();
    let workers = resolve(threads).min(n / MIN_ITEMS_PER_WORKER).max(1);
    if workers <= 1 {
        let mut scratch = init();
        return items
            .iter()
            .enumerate()
            .map(|(i, t)| f(&mut scratch, i, t))
            .collect();
    }
    let f = &f;
    let init = &init;
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                scope.spawn(move || {
                    let mut scratch = init();
                    let mut results = Vec::with_capacity(n / workers + 1);
                    let mut i = w;
                    while i < n {
                        results.push((i, f(&mut scratch, i, &items[i])));
                        i += workers;
                    }
                    results
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("par worker panicked") {
                out[i] = Some(r);
            }
        }
    });
    out.into_iter()
        .map(|r| r.expect("every slot is filled"))
        .collect()
}

/// Deterministic parallel for-each over a mutable slice: applies `f` to
/// every item in place, dealing items to workers in strides. Unlike
/// [`map`], there is **no** minimum-items gate: this drives coarse-grained
/// work (one simulation shard per item), where even two items are worth a
/// thread each. With `threads <= 1` (or a single item) it runs inline, in
/// item order, on the caller's thread.
pub fn for_each_mut<T, F>(threads: usize, items: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    let workers = resolve(threads).min(n).max(1);
    if workers <= 1 {
        for (i, t) in items.iter_mut().enumerate() {
            f(i, t);
        }
        return;
    }
    let f = &f;
    // strided deal matching `map_with`: worker w owns items w, w+W, ...
    // Split the slice into per-worker bundles of &mut references so each
    // worker has exclusive access to its stride.
    let mut bundles: Vec<Vec<(usize, &mut T)>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, t) in items.iter_mut().enumerate() {
        bundles[i % workers].push((i, t));
    }
    std::thread::scope(|scope| {
        for bundle in bundles {
            scope.spawn(move || {
                for (i, t) in bundle {
                    f(i, t);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_zero_is_auto() {
        assert!(resolve(0) >= 1);
        assert_eq!(resolve(3), 3);
    }

    #[test]
    fn map_preserves_item_order() {
        let items: Vec<u64> = (0..100).collect();
        let serial = map(1, &items, |_, &x| x * x);
        let parallel = map(4, &items, |_, &x| x * x);
        assert_eq!(serial, parallel);
        assert_eq!(parallel[7], 49);
    }

    #[test]
    fn map_handles_empty_and_singleton() {
        let empty: Vec<u32> = Vec::new();
        assert!(map(4, &empty, |_, &x| x).is_empty());
        assert_eq!(map(4, &[5u32], |_, &x| x + 1), vec![6]);
    }

    #[test]
    fn small_inputs_stay_inline_and_large_fan_out() {
        // under MIN_ITEMS_PER_WORKER items per worker the pool is skipped;
        // results are identical either way
        let small: Vec<u64> = (0..MIN_ITEMS_PER_WORKER as u64).collect();
        let big: Vec<u64> = (0..64).collect();
        assert_eq!(map(8, &small, |_, &x| x + 1), map(1, &small, |_, &x| x + 1));
        assert_eq!(map(8, &big, |_, &x| x + 1), map(1, &big, |_, &x| x + 1));
    }

    #[test]
    fn scratch_is_reused_within_a_worker() {
        let items: Vec<usize> = (0..32).collect();
        // the scratch buffer accumulates across a worker's items; every
        // item still computes from its own input only
        let results = map_with(
            2,
            &items,
            Vec::<usize>::new,
            |scratch, _, &x| {
                scratch.push(x);
                x * 2
            },
        );
        assert_eq!(results, items.iter().map(|&x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn index_argument_matches_position() {
        let items = ["a", "b", "c"];
        let got = map(3, &items, |i, &s| format!("{i}{s}"));
        assert_eq!(got, vec!["0a", "1b", "2c"]);
    }

    #[test]
    fn for_each_mut_visits_every_item_once() {
        // no min-items gate: even 2 items fan out at threads=2, and the
        // result is identical to the serial path
        for threads in [1, 2, 8] {
            let mut items: Vec<u64> = (0..5).collect();
            for_each_mut(threads, &mut items, |i, x| *x = *x * 10 + i as u64);
            assert_eq!(items, vec![0, 11, 22, 33, 44]);
        }
        let mut empty: Vec<u64> = Vec::new();
        for_each_mut(4, &mut empty, |_, _| unreachable!());
    }
}
