//! Tiny argument-parsing substrate (no offline `clap` in this image).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments — everything the `heye` CLI and the figure harnesses need.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit token list (testable) — `std::env::args()`
    /// minus the binary name in production.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut out = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("run vr --servers 3 --seed=42 --verbose");
        assert_eq!(a.positional, vec!["run", "vr"]);
        assert_eq!(a.get("servers"), Some("3"));
        assert_eq!(a.get_u64("seed", 0), 42);
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn typed_accessors_fall_back() {
        let a = parse("--n notanumber");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
    }

    #[test]
    fn flag_at_end_is_boolean() {
        let a = parse("--x 1 --y");
        assert_eq!(a.get("x"), Some("1"));
        assert_eq!(a.get("y"), Some("true"));
    }
}
