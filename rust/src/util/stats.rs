//! Streaming statistics substrate: Welford accumulators, percentiles,
//! and the latency summaries every experiment harness reports.

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Sample collector with exact percentiles (sorts on query).
///
/// NaN samples are dropped at insertion: one poisoned latency must yield a
/// finite summary over the remaining samples, not abort the whole run (the
/// sort previously `unwrap`ped `partial_cmp` and panicked on NaN) or smear
/// NaN through the mean and the top percentiles. Infinities are kept —
/// they order fine and legitimately represent unreachable placements.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // total_cmp: never panics — NaN is filtered at push, but a
            // total order keeps the sort safe under any future float
            self.xs.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = p / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let frac = rank - lo as f64;
            self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.first().copied().unwrap_or(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.last().copied().unwrap_or(0.0)
    }

    pub fn summary(&mut self) -> Summary {
        Summary {
            n: self.len(),
            mean: self.mean(),
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
            min: self.min(),
            max: self.max(),
        }
    }
}

/// One-line latency summary used across harness tables.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} min={:.3} max={:.3}",
            self.n, self.mean, self.p50, self.p95, self.p99, self.min, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_basic() {
        let mut w = Welford::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        assert_eq!(w.count(), 4);
        assert!((w.mean() - 2.5).abs() < 1e-12);
        assert!((w.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 4.0);
    }

    #[test]
    fn welford_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            all.push(x);
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn percentiles_exact_on_known_data() {
        let mut s = Samples::new();
        s.extend(&[10.0, 20.0, 30.0, 40.0, 50.0]);
        assert_eq!(s.median(), 30.0);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(100.0), 50.0);
        assert!((s.percentile(25.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        let sum = s.summary();
        assert_eq!(sum.n, 100);
        assert!((sum.mean - 50.5).abs() < 1e-12);
        assert!((sum.p50 - 50.5).abs() < 1.0);
        assert!(sum.p95 > 94.0 && sum.p95 < 97.0);
    }

    /// Regression: a NaN latency sample used to abort the whole run via
    /// `partial_cmp(...).unwrap()` in the percentile sort. It must instead
    /// yield a finite summary over the valid samples.
    #[test]
    fn nan_sample_yields_finite_summary_not_panic() {
        let mut s = Samples::new();
        s.extend(&[0.010, f64::NAN, 0.030, 0.020]);
        let sum = s.summary();
        assert_eq!(sum.n, 3, "the NaN sample is dropped");
        assert!(sum.mean.is_finite() && (sum.mean - 0.020).abs() < 1e-12);
        assert!(sum.p50.is_finite() && (sum.p50 - 0.020).abs() < 1e-12);
        assert!(sum.p95.is_finite() && sum.max.is_finite());
        assert_eq!(sum.max, 0.030);
        // all-NaN degenerates to the empty summary, still finite
        let mut all_nan = Samples::new();
        all_nan.push(f64::NAN);
        let sum = all_nan.summary();
        assert_eq!(sum.n, 0);
        assert!(sum.mean.is_finite() && sum.p99.is_finite());
    }

    #[test]
    fn empty_collections_are_safe() {
        let mut s = Samples::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        let w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.var(), 0.0);
    }
}
