//! Streaming statistics substrate: Welford accumulators, percentiles,
//! and the latency summaries every experiment harness reports.

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Sample collector with exact percentiles (sorts on query).
///
/// NaN samples are dropped at insertion: one poisoned latency must yield a
/// finite summary over the remaining samples, not abort the whole run (the
/// sort previously `unwrap`ped `partial_cmp` and panicked on NaN) or smear
/// NaN through the mean and the top percentiles. Infinities are kept —
/// they order fine and legitimately represent unreachable placements.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // total_cmp: never panics — NaN is filtered at push, but a
            // total order keeps the sort safe under any future float
            self.xs.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, `p` in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let rank = p / 100.0 * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let frac = rank - lo as f64;
            self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
        }
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.first().copied().unwrap_or(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs.last().copied().unwrap_or(0.0)
    }

    pub fn summary(&mut self) -> Summary {
        Summary {
            n: self.len(),
            mean: self.mean(),
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
            min: self.min(),
            max: self.max(),
        }
    }
}

/// Log-bucketed histogram: bucket `i` covers `[lo * growth^i, lo *
/// growth^(i+1))`, so relative resolution is a constant `growth` factor at
/// any magnitude — the right shape for latencies spanning microseconds to
/// seconds. Values below `lo` (including zero and negatives) land in a
/// dedicated underflow bucket; NaN and non-finite values are dropped like
/// [`Samples`] drops NaN. Buckets are integer counts, so
/// [`LogHistogram::merge`] is exact and associative on everything except
/// the float `sum`.
#[derive(Debug, Clone, PartialEq)]
pub struct LogHistogram {
    lo: f64,
    growth: f64,
    /// cached `growth.ln()` — derived from `growth`, never diverges
    log_growth: f64,
    counts: Vec<u64>,
    underflow: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl LogHistogram {
    /// `lo` is the smallest resolvable value (> 0), `growth` the per-bucket
    /// width factor (> 1). Panics on invalid parameters — the two numbers
    /// are compile-time-ish choices, not data.
    pub fn new(lo: f64, growth: f64) -> Self {
        assert!(lo > 0.0 && lo.is_finite(), "LogHistogram lo must be > 0");
        assert!(
            growth > 1.0 && growth.is_finite(),
            "LogHistogram growth must be > 1"
        );
        Self {
            lo,
            growth,
            log_growth: growth.ln(),
            counts: Vec::new(),
            underflow: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Default shape for latency-like seconds: 1 µs floor, 25% buckets
    /// (~104 buckets to reach 1e4 s).
    pub fn latency() -> Self {
        Self::new(1e-6, 1.25)
    }

    pub fn push(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if v < self.lo {
            self.underflow += 1;
            return;
        }
        // float error on a boundary value may land it one bucket early or
        // late; either way the bucket edges still bound it within `growth`
        let idx = ((v / self.lo).ln() / self.log_growth).floor().max(0.0) as usize;
        if idx >= self.counts.len() {
            self.counts.resize(idx + 1, 0);
        }
        self.counts[idx] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Lower edge of bucket `i`.
    pub fn bucket_lo(&self, i: usize) -> f64 {
        self.lo * self.growth.powi(i as i32)
    }

    /// Non-empty buckets as `(lo_edge, hi_edge, count)`, underflow excluded.
    pub fn buckets(&self) -> impl Iterator<Item = (f64, f64, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (self.bucket_lo(i), self.bucket_lo(i + 1), c))
    }

    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    /// Merge `other` into `self`. Both histograms must share `(lo, growth)`
    /// — merging differently-shaped histograms is a programming error.
    pub fn merge(&mut self, other: &LogHistogram) {
        assert!(
            self.lo == other.lo && self.growth == other.growth,
            "LogHistogram::merge requires identical (lo, growth)"
        );
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (i, &c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
        self.underflow += other.underflow;
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Upper bucket edge of the `ceil(q * count)`-th smallest recorded
    /// value (`q` clamped to [0, 1]). For any recorded value `v >= lo`
    /// at that rank the estimate `e` satisfies `v <= e <= v * growth` (up
    /// to float rounding); ranks that fall in the underflow bucket report
    /// `lo`. Empty histograms report NaN.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = self.underflow;
        if rank <= seen {
            return self.lo;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if rank <= seen {
                return self.bucket_lo(i + 1);
            }
        }
        // only reachable when every value is non-finite-filtered (counts
        // empty but count > 0 cannot happen); fall back to max
        self.max
    }
}

/// One-line latency summary used across harness tables.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} p50={:.3} p95={:.3} p99={:.3} min={:.3} max={:.3}",
            self.n, self.mean, self.p50, self.p95, self.p99, self.min, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_basic() {
        let mut w = Welford::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            w.push(x);
        }
        assert_eq!(w.count(), 4);
        assert!((w.mean() - 2.5).abs() < 1e-12);
        assert!((w.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 4.0);
    }

    #[test]
    fn welford_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Welford::new();
        let mut a = Welford::new();
        let mut b = Welford::new();
        for (i, &x) in xs.iter().enumerate() {
            all.push(x);
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.var() - all.var()).abs() < 1e-9);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn percentiles_exact_on_known_data() {
        let mut s = Samples::new();
        s.extend(&[10.0, 20.0, 30.0, 40.0, 50.0]);
        assert_eq!(s.median(), 30.0);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(100.0), 50.0);
        assert!((s.percentile(25.0) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        let sum = s.summary();
        assert_eq!(sum.n, 100);
        assert!((sum.mean - 50.5).abs() < 1e-12);
        assert!((sum.p50 - 50.5).abs() < 1.0);
        assert!(sum.p95 > 94.0 && sum.p95 < 97.0);
    }

    /// Regression: a NaN latency sample used to abort the whole run via
    /// `partial_cmp(...).unwrap()` in the percentile sort. It must instead
    /// yield a finite summary over the valid samples.
    #[test]
    fn nan_sample_yields_finite_summary_not_panic() {
        let mut s = Samples::new();
        s.extend(&[0.010, f64::NAN, 0.030, 0.020]);
        let sum = s.summary();
        assert_eq!(sum.n, 3, "the NaN sample is dropped");
        assert!(sum.mean.is_finite() && (sum.mean - 0.020).abs() < 1e-12);
        assert!(sum.p50.is_finite() && (sum.p50 - 0.020).abs() < 1e-12);
        assert!(sum.p95.is_finite() && sum.max.is_finite());
        assert_eq!(sum.max, 0.030);
        // all-NaN degenerates to the empty summary, still finite
        let mut all_nan = Samples::new();
        all_nan.push(f64::NAN);
        let sum = all_nan.summary();
        assert_eq!(sum.n, 0);
        assert!(sum.mean.is_finite() && sum.p99.is_finite());
    }

    #[test]
    fn empty_collections_are_safe() {
        let mut s = Samples::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        let w = Welford::new();
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.var(), 0.0);
        let h = LogHistogram::latency();
        assert_eq!(h.count(), 0);
        assert!(h.quantile(0.5).is_nan());
    }

    #[test]
    fn log_histogram_buckets_and_underflow() {
        let mut h = LogHistogram::new(1e-3, 2.0);
        for v in [0.0, -1.0, 5e-4, 1.5e-3, 3e-3, 3.5e-3, 0.1, f64::NAN] {
            h.push(v);
        }
        assert_eq!(h.count(), 7, "NaN dropped, everything else counted");
        assert_eq!(h.underflow(), 3, "zero, negative, and sub-lo values");
        let buckets: Vec<_> = h.buckets().collect();
        // 1.5e-3 -> [1e-3, 2e-3); 3e-3 and 3.5e-3 -> [2e-3, 4e-3); 0.1 high
        assert_eq!(buckets[0].2, 1);
        assert_eq!(buckets[1].2, 2);
        assert_eq!(h.min(), -1.0);
        assert_eq!(h.max(), 0.1);
    }

    /// A log histogram drawn from random samples, for property tests.
    fn random_hist(
        rng: &mut crate::util::rng::Rng,
        lo: f64,
        growth: f64,
        n: usize,
    ) -> (LogHistogram, Vec<f64>) {
        let mut h = LogHistogram::new(lo, growth);
        let mut xs = Vec::with_capacity(n);
        for _ in 0..n {
            // log-uniform over six decades above lo
            let v = lo * 10f64.powf(rng.range_f64(0.0, 6.0));
            h.push(v);
            xs.push(v);
        }
        (h, xs)
    }

    /// Merge is associative: counts, extrema, and quantiles are integer /
    /// order-statistic derived, so they must match exactly; only the float
    /// `sum` is allowed rounding slack.
    #[test]
    fn log_histogram_merge_associative() {
        crate::util::prop::check("hist-merge-assoc", crate::util::prop::default_cases(), |rng| {
            let (lo, growth) = (1e-6, 1.25);
            let (a, _) = random_hist(rng, lo, growth, rng.range(1, 50));
            let (b, _) = random_hist(rng, lo, growth, rng.range(1, 50));
            let (c, _) = random_hist(rng, lo, growth, rng.range(1, 50));
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            if left.count() != right.count()
                || left.underflow() != right.underflow()
                || left.min() != right.min()
                || left.max() != right.max()
                || left.buckets().collect::<Vec<_>>() != right.buckets().collect::<Vec<_>>()
            {
                return Err("count/bucket state differs by merge order".into());
            }
            for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                if left.quantile(q) != right.quantile(q) {
                    return Err(format!("quantile({q}) differs by merge order"));
                }
            }
            let rel = (left.sum() - right.sum()).abs() / right.sum().abs().max(1e-300);
            if rel > 1e-9 {
                return Err(format!("sum diverged beyond rounding: rel {rel}"));
            }
            Ok(())
        });
    }

    /// `quantile(q)` brackets the exact order statistic at the same rank
    /// within one `growth` factor, and the endpoints bracket the exact
    /// [`Samples`] p0/p100.
    #[test]
    fn log_histogram_quantile_bounds_vs_exact_samples() {
        crate::util::prop::check("hist-quantile-bounds", crate::util::prop::default_cases(), |rng| {
            let growth = 1.0 + rng.range_f64(0.1, 1.0);
            let (h, mut xs) = random_hist(rng, 1e-6, growth, rng.range(1, 200));
            let mut samples = Samples::new();
            samples.extend(&xs);
            xs.sort_by(|a, b| a.total_cmp(b));
            let n = xs.len();
            let slack = 1.0 + 1e-9;
            for q in [0.1, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
                // same rank convention as LogHistogram::quantile
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let exact = xs[rank - 1];
                let est = h.quantile(q);
                if est < exact / slack || est > exact * growth * slack {
                    return Err(format!(
                        "quantile({q}) = {est} outside [{exact}, {}]",
                        exact * growth
                    ));
                }
            }
            // exact Samples endpoints (no interpolation at p0/p100)
            let (p0, p100) = (samples.percentile(0.0), samples.percentile(100.0));
            if h.quantile(0.0) < p0 / slack || h.quantile(1.0) > p100 * growth * slack {
                return Err("endpoints escaped the exact Samples bounds".into());
            }
            Ok(())
        });
    }
}
