//! From-scratch substrates the offline image lacks crates for:
//! PRNG, JSON, CLI parsing, streaming stats, a micro-bench harness, and a
//! property-testing helper. Everything above this module depends only on
//! `std`, `anyhow`/`thiserror`, and `xla`.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
