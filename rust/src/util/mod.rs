//! From-scratch substrates the offline image lacks crates for:
//! error handling, PRNG, JSON, CLI parsing, streaming stats, a micro-bench
//! harness, a property-testing helper, and a scoped worker pool. Everything
//! above this module depends only on `std` (plus `xla` behind the optional
//! `pjrt` feature).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
