//! From-scratch substrates the offline image lacks crates for:
//! error handling, PRNG, JSON, CLI parsing, streaming stats, a micro-bench
//! harness, a property-testing helper, and a scoped worker pool. Everything
//! above this module depends only on `std` (plus `xla` behind the optional
//! `pjrt` feature).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;

/// Process-wide cached boolean env flag: the variable being *set* (to any
/// value, including empty) means `true`. Each flag is resolved from the
/// environment exactly once per process, so hot paths may query it freely;
/// later `std::env::set_var` calls are intentionally not observed, which
/// keeps the answer stable for the lifetime of a run.
pub fn env_flag(name: &str) -> bool {
    use std::collections::BTreeMap;
    use std::sync::{Mutex, OnceLock};
    static FLAGS: OnceLock<Mutex<BTreeMap<String, bool>>> = OnceLock::new();
    let flags = FLAGS.get_or_init(|| Mutex::new(BTreeMap::new()));
    let mut cached = flags.lock().unwrap_or_else(|e| e.into_inner());
    *cached
        .entry(name.to_string())
        .or_insert_with(|| std::env::var(name).is_ok())
}
