//! Micro-benchmark harness substrate (no offline `criterion` in this image).
//!
//! Every `benches/*.rs` target uses `harness = false` and drives this module:
//! warmup, timed iterations, median/p95 reporting, and aligned table output
//! that mirrors the paper's figure series.
//!
//! The CI bench gate is also here: [`results_json`] serializes a run to the
//! `BENCH_*.json` schema and [`gate`] compares it against a committed
//! baseline with a tolerance multiplier (see `.github/workflows/ci.yml`;
//! refresh the baseline by re-running the bench with `--json` on a quiet
//! machine and committing the output).

use std::time::Instant;

use super::json::Json;
use super::stats::Samples;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Samples::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: samples.mean(),
        p50_ns: samples.percentile(50.0),
        p95_ns: samples.percentile(95.0),
        min_ns: samples.min(),
    }
}

/// Print a set of results as an aligned table.
pub fn report(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12}",
        "case", "iters", "mean", "p50", "p95"
    );
    for r in results {
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12}",
            r.name,
            r.iters,
            fmt_ns(r.mean_ns),
            fmt_ns(r.p50_ns),
            fmt_ns(r.p95_ns)
        );
    }
}

/// Serialize a bench run to the stable `BENCH_*.json` schema the CI gate
/// consumes: `{"label": ..., "cases": {name: {iters, mean_ns, p50_ns,
/// p95_ns, min_ns}}}`.
pub fn results_json(label: &str, results: &[BenchResult]) -> Json {
    let cases = results
        .iter()
        .map(|r| {
            (
                r.name.clone(),
                Json::obj(vec![
                    ("iters", Json::Num(r.iters as f64)),
                    ("mean_ns", Json::Num(r.mean_ns)),
                    ("p50_ns", Json::Num(r.p50_ns)),
                    ("p95_ns", Json::Num(r.p95_ns)),
                    ("min_ns", Json::Num(r.min_ns)),
                ]),
            )
        })
        .collect();
    Json::obj(vec![
        ("label", Json::Str(label.to_string())),
        ("cases", Json::Obj(cases)),
    ])
}

/// Benchmark-regression gate: every case present in both the committed
/// `baseline` and `results` must keep its p50 within `tol` x the baseline
/// p50 (p50 rides out scheduler noise better than the mean; the generous
/// default tolerance in CI absorbs runner-hardware variance while still
/// catching order-of-magnitude regressions). Returns the violation
/// messages — empty means the gate passes. Cases missing from the baseline
/// are reported as notes by the caller, not failures, so adding a bench
/// case never breaks CI before the baseline is refreshed.
pub fn gate(baseline: &Json, results: &[BenchResult], tol: f64) -> Vec<String> {
    let cases = match baseline.get("cases").and_then(|c| c.as_obj()) {
        Some(c) => c,
        None => return vec!["baseline has no `cases` object".to_string()],
    };
    let mut violations = Vec::new();
    for r in results {
        let base = cases
            .get(&r.name)
            .and_then(|c| c.get("p50_ns"))
            .and_then(|v| v.as_f64());
        let base = match base {
            Some(b) if b > 0.0 => b,
            _ => continue,
        };
        if r.p50_ns > base * tol {
            violations.push(format!(
                "{}: p50 {} exceeds {tol:.1}x the committed baseline {} ({:.1}x)",
                r.name,
                fmt_ns(r.p50_ns),
                fmt_ns(base),
                r.p50_ns / base
            ));
        }
    }
    violations
}

/// Human duration formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Aligned series table used by the figure harnesses: a header row plus
/// data rows, each a label and f64 columns.
pub struct FigureTable {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
}

impl FigureTable {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, label: impl Into<String>, values: Vec<f64>) {
        let v = values;
        assert_eq!(v.len(), self.columns.len(), "column arity mismatch");
        self.rows.push((label.into(), v));
    }

    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        print!("{:<36}", "");
        for c in &self.columns {
            print!(" {c:>14}");
        }
        println!();
        for (label, vals) in &self.rows {
            print!("{label:<36}");
            for v in vals {
                if v.abs() >= 1000.0 || (*v != 0.0 && v.abs() < 0.01) {
                    print!(" {v:>14.3e}");
                } else {
                    print!(" {v:>14.3}");
                }
            }
            println!();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_timings() {
        let r = bench("noop-ish", 2, 20, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 20);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }

    #[test]
    #[should_panic(expected = "column arity mismatch")]
    fn figure_table_arity_checked() {
        let mut t = FigureTable::new("t", &["a", "b"]);
        t.row("x", vec![1.0]);
    }

    fn result(name: &str, p50: f64) -> BenchResult {
        BenchResult {
            name: name.to_string(),
            iters: 10,
            mean_ns: p50,
            p50_ns: p50,
            p95_ns: p50 * 1.2,
            min_ns: p50 * 0.9,
        }
    }

    #[test]
    fn results_json_roundtrips_through_the_parser() {
        let j = results_json("hot paths", &[result("a", 1000.0), result("b", 2e6)]);
        let back = Json::parse(&j.to_string()).expect("reparse");
        assert_eq!(back.get("label").and_then(|l| l.as_str()), Some("hot paths"));
        let p50 = back
            .get("cases")
            .and_then(|c| c.get("a"))
            .and_then(|a| a.get("p50_ns"))
            .and_then(|v| v.as_f64());
        assert_eq!(p50, Some(1000.0));
    }

    #[test]
    fn gate_passes_within_tolerance_and_fails_beyond() {
        let baseline = results_json("base", &[result("a", 1000.0), result("b", 1000.0)]);
        // within 2x: pass
        assert!(gate(&baseline, &[result("a", 1900.0)], 2.0).is_empty());
        // beyond 2x: violation names the case
        let v = gate(&baseline, &[result("b", 2100.0)], 2.0);
        assert_eq!(v.len(), 1);
        assert!(v[0].starts_with("b:"), "{v:?}");
        // unknown case: ignored, not a failure
        assert!(gate(&baseline, &[result("new-case", 9e9)], 2.0).is_empty());
        // malformed baseline: reported
        assert!(!gate(&Json::Null, &[result("a", 1.0)], 2.0).is_empty());
    }
}
