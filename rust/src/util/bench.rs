//! Micro-benchmark harness substrate (no offline `criterion` in this image).
//!
//! Every `benches/*.rs` target uses `harness = false` and drives this module:
//! warmup, timed iterations, median/p95 reporting, and aligned table output
//! that mirrors the paper's figure series.

use std::time::Instant;

use super::stats::Samples;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Samples::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: samples.mean(),
        p50_ns: samples.percentile(50.0),
        p95_ns: samples.percentile(95.0),
        min_ns: samples.min(),
    }
}

/// Print a set of results as an aligned table.
pub fn report(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12}",
        "case", "iters", "mean", "p50", "p95"
    );
    for r in results {
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12}",
            r.name,
            r.iters,
            fmt_ns(r.mean_ns),
            fmt_ns(r.p50_ns),
            fmt_ns(r.p95_ns)
        );
    }
}

/// Human duration formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Aligned series table used by the figure harnesses: a header row plus
/// data rows, each a label and f64 columns.
pub struct FigureTable {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
}

impl FigureTable {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, label: impl Into<String>, values: Vec<f64>) {
        let v = values;
        assert_eq!(v.len(), self.columns.len(), "column arity mismatch");
        self.rows.push((label.into(), v));
    }

    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        print!("{:<36}", "");
        for c in &self.columns {
            print!(" {c:>14}");
        }
        println!();
        for (label, vals) in &self.rows {
            print!("{label:<36}");
            for v in vals {
                if v.abs() >= 1000.0 || (*v != 0.0 && v.abs() < 0.01) {
                    print!(" {v:>14.3e}");
                } else {
                    print!(" {v:>14.3}");
                }
            }
            println!();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_timings() {
        let r = bench("noop-ish", 2, 20, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 20);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }

    #[test]
    #[should_panic(expected = "column arity mismatch")]
    fn figure_table_arity_checked() {
        let mut t = FigureTable::new("t", &["a", "b"]);
        t.row("x", vec![1.0]);
    }
}
