//! Deterministic PRNG substrate (no offline `rand` crate in this image).
//!
//! `SplitMix64` seeds a `Xoshiro256StarStar` generator — the standard
//! combination with good statistical properties and trivially reproducible
//! streams, which every simulator experiment here keys off an explicit seed.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// One-shot SplitMix64 mix of two words into a well-distributed 64-bit
/// value. Used to derive independent, *stable* per-source RNG streams from
/// a run seed plus structural identifiers (origin id, per-origin index),
/// so adding or removing one stream never perturbs the others.
pub fn mix64(a: u64, b: u64) -> u64 {
    let mut sm = SplitMix64::new(a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    sm.next_u64()
}

/// Xoshiro256** — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream (used to give each device / arrival
    /// process its own generator without cross-correlation).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire-style rejection-free-enough for simulation purposes
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Exponential inter-arrival with the given rate (events/sec).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -(1.0 - self.f64()).ln() / rate
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn mix64_is_stable_and_spreads() {
        // stable: pure function of its inputs
        assert_eq!(mix64(42, 7), mix64(42, 7));
        // spreads: nearby keys land far apart
        let vals: Vec<u64> = (0..32).map(|k| mix64(42, k)).collect();
        let mut uniq = vals.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), vals.len());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
