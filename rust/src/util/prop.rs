//! Property-testing substrate (no offline `proptest` in this image).
//!
//! `check` runs a closure across many seeded Rngs and reports the first
//! failing seed, so a failure is reproducible with
//! `PROP_SEED=<seed> cargo test <name>`. Coordinator invariants (routing,
//! batching, state consistency) are verified this way in `rust/tests/`.

use super::rng::Rng;

/// Number of cases per property; override with env `PROP_CASES`.
pub fn default_cases() -> u64 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `property` with `cases` independently-seeded Rngs. The closure
/// returns `Err(msg)` (or panics) to signal a violation.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // single-seed reproduction path
    if let Ok(seed) = std::env::var("PROP_SEED") {
        let seed: u64 = seed.parse().expect("PROP_SEED must be a u64");
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property `{name}` failed at PROP_SEED={seed}: {msg}");
        }
        return;
    }
    let base: u64 = 0x5EED_0000;
    for case in 0..cases {
        let seed = base + case;
        let mut rng = Rng::new(seed);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => panic!(
                "property `{name}` failed on case {case} (reproduce with PROP_SEED={seed}): {msg}"
            ),
            Err(p) => {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "panic".to_string());
                panic!(
                    "property `{name}` panicked on case {case} (reproduce with PROP_SEED={seed}): {msg}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-true", 16, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 16);
    }

    #[test]
    #[should_panic(expected = "reproduce with PROP_SEED=")]
    fn failing_property_reports_seed() {
        check("always-false", 4, |_rng| Err("nope".to_string()));
    }

    #[test]
    #[should_panic(expected = "panicked on case")]
    fn panicking_property_is_caught() {
        check("panics", 2, |_rng| panic!("boom"));
    }
}
