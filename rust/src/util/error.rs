//! Minimal error substrate (no offline `anyhow`/`thiserror` in this image).
//!
//! A string-backed [`Error`], a defaulted [`Result`] alias, the [`err!`] /
//! [`bail!`] macros, and a [`Context`] extension trait — the subset of the
//! anyhow surface the crate actually uses.

use std::fmt;

/// A string-backed error. Every fallible path in the crate funnels into
/// this type; context is accumulated by prefixing.
pub struct Error(String);

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// `main() -> Result<..>` prints the error through Debug: keep it readable.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => { $crate::util::error::Error::msg(format!($($arg)*)) };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::err!($($arg)*).into()) };
}

/// Attach context to a `Result` or `Option`, anyhow-style.
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error(msg.to_string()))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("bad value {}", 42);
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "bad value 42");
        assert_eq!(format!("{e:?}"), "bad value 42");
    }

    #[test]
    fn context_prefixes() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.context("opening manifest").unwrap_err();
        assert!(e.to_string().starts_with("opening manifest: "));
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }
}
