//! Structured tracing and metrics: deterministic event traces, Chrome
//! trace-event export, and scheduling-overhead reconstruction.
//!
//! A [`Tracer`] lives inside each engine shard and records typed
//! [`TraceEvent`]s (frame release / scheduler decision / transfer /
//! execution span / completion, cross-domain handoffs and sync barriers,
//! membership joins / leaves / re-registrations / drain escalations,
//! admission queueing) into a per-shard append-only buffer stamped with
//! simulated time. Tracing is **zero-cost when disabled**: `emit` takes the
//! event as a closure and checks one `bool` before building anything, and
//! `RunMetrics` are byte-identical trace-on vs trace-off (asserted in
//! `tests/trace.rs`).
//!
//! ## Determinism invariants
//!
//! Each shard's buffer is filled by that shard's deterministic event loop,
//! so the buffers are identical for any worker count; [`Trace::assemble`]
//! concatenates them in shard-id order and tags every record with
//! `(shard, seq)`. Serialization ([`Trace::to_chrome_json`]) orders
//! records by `(t, shard, seq)` over sorted-key objects, so the trace
//! *output is byte-identical for any worker count >= 1*.
//!
//! Two channels keep that invariant honest:
//!
//! * the **simulated-time channel** (everything above) is a pure function
//!   of the configuration;
//! * the optional **wall-clock channel** ([`TraceSpec::wall`]) adds one
//!   [`TraceEvent::SchedWall`] per scheduler decision carrying the
//!   *measured* `Overhead::compute_s` — real nondeterministic wall time,
//!   excluded from byte-identity assertions and off by default.
//!
//! ## Overhead reconstruction
//!
//! [`Trace::overhead_report`] re-derives the engine's `Overhead`
//! accounting **from the trace alone**, replaying the same accumulation
//! order the engine used (per-shard sequence order, shard-order merge,
//! completion-order frame-compute sum) so the floats match the engine's
//! `RunMetrics` bit for bit — `heye trace overhead out.json` prints the
//! paper's <2%-scheduling-overhead budget report from a file.
//!
//! ## Chrome trace-event schema
//!
//! The export is a standard Chrome trace-event JSON object (loadable in
//! Perfetto / `chrome://tracing`): `{"displayTimeUnit": "ms", "heye":
//! {meta}, "traceEvents": [...]}` with one *process* per orchestration
//! domain (shard) and one *thread* per device; execution spans and
//! transfers are `"ph": "X"` duration events, everything else is an
//! instant (`"ph": "i"`), and `"M"` metadata events name the tracks.
//! Perfetto ignores the extra `"heye"` object and the raw per-event
//! fields under `"args"`, which is where [`Trace::from_json`] reads the
//! full-precision values back (the `ts` microseconds are display-only).

use std::collections::{BTreeMap, BTreeSet};

use crate::util::json::Json;
use crate::util::stats::LogHistogram;

/// Trace-file schema version (the `"heye"."schema"` field).
pub const SCHEMA_VERSION: u64 = 1;

/// Synthetic Chrome thread id for events that belong to the orchestrator
/// itself rather than a device track.
const ORC_TID: u64 = 999_999;

/// Tracing knobs, carried by `sim::ExecOpts`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceSpec {
    /// record the deterministic simulated-time event channel
    pub enabled: bool,
    /// additionally record measured wall-clock scheduler compute seconds
    /// (one [`TraceEvent::SchedWall`] per decision) — nondeterministic by
    /// nature, so it is opt-in and excluded from byte-identity tests
    pub wall: bool,
}

/// The structured stderr seam: every ad-hoc diagnostic the crate used to
/// `eprintln!` directly funnels through here with a topic tag, so headless
/// bench runs capture one greppable `[heye::<topic>] ...` format.
pub fn log_line(topic: &str, msg: std::fmt::Arguments<'_>) {
    eprintln!("[heye::{topic}] {msg}");
}

// ---------------------------------------------------------------------------
// events
// ---------------------------------------------------------------------------

/// One typed trace event. Ids are raw (`NodeId::0` widened to `u64`) so the
/// trace file is self-contained.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// a source released a frame
    FrameRelease { frame: u64, origin: u64 },
    /// the admission controller refused an arrival outright (`class` is
    /// the [`crate::task::QosClass`] discriminant: 0 interactive,
    /// 1 standard, 2 bulk — interactive never sheds by policy)
    FrameShed { origin: u64, class: u64 },
    /// the admission controller deferred a standard-class arrival into
    /// the bounded queue; `depth` is the queue depth after the deferral
    FrameDeferred { origin: u64, depth: u64 },
    /// one scheduler MapTask decision — the deterministic half of the
    /// engine's `Overhead` accounting (`dev` is `None` when the decision
    /// escalated to a foreign domain instead of placing locally)
    SchedDecision {
        frame: u64,
        node: u64,
        dev: Option<u64>,
        comm_s: f64,
        hops: u64,
        calls: u64,
        escalated: bool,
        degraded: bool,
    },
    /// wall-clock channel: measured constraint-check seconds of the
    /// immediately preceding decision
    SchedWall { compute_s: f64 },
    /// a cross-device input transfer opened for a placed task
    Transfer {
        frame: u64,
        node: u64,
        from: u64,
        to: u64,
        bytes: f64,
        delay_s: f64,
    },
    /// a task's execution span on a PU (recorded at completion; the record
    /// time is the end of the span)
    ExecSpan {
        frame: u64,
        node: u64,
        device: u64,
        pu: u64,
        start_t: f64,
    },
    /// admission control queued a ready task behind the tenancy cap
    Queued {
        frame: u64,
        node: u64,
        device: u64,
        pu: u64,
    },
    /// a frame completed (the record time is its finish time)
    FrameComplete {
        frame: u64,
        origin: u64,
        release_t: f64,
        latency_s: f64,
        compute_s: f64,
        qos_ok: bool,
        degraded: bool,
    },
    /// a sub-ORC miss escalated across domains (send side)
    HandoffSend {
        frame: u64,
        node: u64,
        from_domain: u64,
        to_domain: u64,
        cross_s: f64,
    },
    /// a handoff arrived at the target domain's ingress
    HandoffRecv { from_domain: u64, to_domain: u64 },
    /// a remote stub's result folded back into its home frame
    RemoteDone { frame: u64, node: u64, cross_s: f64 },
    /// a sharded sync barrier delivered cross-domain messages to this shard
    Barrier { window_end: f64, delivered: u64 },
    /// a device joined (scripted join or membership re-registration ride
    /// separate events)
    Join { device: u64 },
    /// a device left — gracefully or by failure (scripted, or synthesized
    /// by a missed heartbeat deadline; the engine keeps the two
    /// byte-identical by design)
    Leave { device: u64, failure: bool },
    /// a flaky device re-registered after a detected failure
    ReRegister { device: u64 },
    /// a graceful drain exceeded its deadline and escalated to the failure
    /// path
    DrainEscalate { device: u64 },
    /// a capability re-advertisement rescaled a device's headroom
    Capability { device: u64, weight: f64 },
}

impl TraceEvent {
    /// Stable kind tag used as the Chrome event name and the `args.kind`
    /// discriminator.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::FrameRelease { .. } => "release",
            TraceEvent::FrameShed { .. } => "shed",
            TraceEvent::FrameDeferred { .. } => "deferred",
            TraceEvent::SchedDecision { .. } => "sched",
            TraceEvent::SchedWall { .. } => "sched_wall",
            TraceEvent::Transfer { .. } => "xfer",
            TraceEvent::ExecSpan { .. } => "exec",
            TraceEvent::Queued { .. } => "queued",
            TraceEvent::FrameComplete { .. } => "frame",
            TraceEvent::HandoffSend { .. } => "handoff_send",
            TraceEvent::HandoffRecv { .. } => "handoff_recv",
            TraceEvent::RemoteDone { .. } => "remote_done",
            TraceEvent::Barrier { .. } => "barrier",
            TraceEvent::Join { .. } => "join",
            TraceEvent::Leave { .. } => "leave",
            TraceEvent::ReRegister { .. } => "rereg",
            TraceEvent::DrainEscalate { .. } => "drain_escalate",
            TraceEvent::Capability { .. } => "capability",
        }
    }

    /// Chrome thread id: the device the event is anchored to, or the
    /// synthetic orchestrator track.
    fn tid(&self) -> u64 {
        match *self {
            TraceEvent::FrameRelease { origin, .. } => origin,
            TraceEvent::FrameShed { origin, .. } => origin,
            TraceEvent::FrameDeferred { origin, .. } => origin,
            TraceEvent::SchedDecision { dev, .. } => dev.unwrap_or(ORC_TID),
            TraceEvent::Transfer { to, .. } => to,
            TraceEvent::ExecSpan { device, .. } => device,
            TraceEvent::Queued { device, .. } => device,
            TraceEvent::FrameComplete { origin, .. } => origin,
            TraceEvent::Join { device }
            | TraceEvent::Leave { device, .. }
            | TraceEvent::ReRegister { device }
            | TraceEvent::DrainEscalate { device }
            | TraceEvent::Capability { device, .. } => device,
            TraceEvent::SchedWall { .. }
            | TraceEvent::HandoffSend { .. }
            | TraceEvent::HandoffRecv { .. }
            | TraceEvent::RemoteDone { .. }
            | TraceEvent::Barrier { .. } => ORC_TID,
        }
    }

    /// Event-specific `args` fields (the common `kind`/`t`/`shard`/`seq`
    /// are added by the exporter).
    fn args(&self) -> Vec<(&'static str, Json)> {
        let num = |v: u64| Json::Num(v as f64);
        match *self {
            TraceEvent::FrameRelease { frame, origin } => {
                vec![("frame", num(frame)), ("origin", num(origin))]
            }
            TraceEvent::FrameShed { origin, class } => {
                vec![("origin", num(origin)), ("class", num(class))]
            }
            TraceEvent::FrameDeferred { origin, depth } => {
                vec![("origin", num(origin)), ("depth", num(depth))]
            }
            TraceEvent::SchedDecision {
                frame,
                node,
                dev,
                comm_s,
                hops,
                calls,
                escalated,
                degraded,
            } => vec![
                ("frame", num(frame)),
                ("node", num(node)),
                ("dev", dev.map(num).unwrap_or(Json::Null)),
                ("comm_s", Json::Num(comm_s)),
                ("hops", num(hops)),
                ("calls", num(calls)),
                ("escalated", Json::Bool(escalated)),
                ("degraded", Json::Bool(degraded)),
            ],
            TraceEvent::SchedWall { compute_s } => vec![("compute_s", Json::Num(compute_s))],
            TraceEvent::Transfer {
                frame,
                node,
                from,
                to,
                bytes,
                delay_s,
            } => vec![
                ("frame", num(frame)),
                ("node", num(node)),
                ("from", num(from)),
                ("to", num(to)),
                ("bytes", Json::Num(bytes)),
                ("delay_s", Json::Num(delay_s)),
            ],
            TraceEvent::ExecSpan {
                frame,
                node,
                device,
                pu,
                start_t,
            } => vec![
                ("frame", num(frame)),
                ("node", num(node)),
                ("device", num(device)),
                ("pu", num(pu)),
                ("start_t", Json::Num(start_t)),
            ],
            TraceEvent::Queued {
                frame,
                node,
                device,
                pu,
            } => vec![
                ("frame", num(frame)),
                ("node", num(node)),
                ("device", num(device)),
                ("pu", num(pu)),
            ],
            TraceEvent::FrameComplete {
                frame,
                origin,
                release_t,
                latency_s,
                compute_s,
                qos_ok,
                degraded,
            } => vec![
                ("frame", num(frame)),
                ("origin", num(origin)),
                ("release_t", Json::Num(release_t)),
                ("latency_s", Json::Num(latency_s)),
                ("compute_s", Json::Num(compute_s)),
                ("qos_ok", Json::Bool(qos_ok)),
                ("degraded", Json::Bool(degraded)),
            ],
            TraceEvent::HandoffSend {
                frame,
                node,
                from_domain,
                to_domain,
                cross_s,
            } => vec![
                ("frame", num(frame)),
                ("node", num(node)),
                ("from_domain", num(from_domain)),
                ("to_domain", num(to_domain)),
                ("cross_s", Json::Num(cross_s)),
            ],
            TraceEvent::HandoffRecv {
                from_domain,
                to_domain,
            } => vec![
                ("from_domain", num(from_domain)),
                ("to_domain", num(to_domain)),
            ],
            TraceEvent::RemoteDone {
                frame,
                node,
                cross_s,
            } => vec![
                ("frame", num(frame)),
                ("node", num(node)),
                ("cross_s", Json::Num(cross_s)),
            ],
            TraceEvent::Barrier {
                window_end,
                delivered,
            } => vec![
                ("window_end", Json::Num(window_end)),
                ("delivered", num(delivered)),
            ],
            TraceEvent::Join { device } => vec![("device", num(device))],
            TraceEvent::Leave { device, failure } => {
                vec![("device", num(device)), ("failure", Json::Bool(failure))]
            }
            TraceEvent::ReRegister { device } => vec![("device", num(device))],
            TraceEvent::DrainEscalate { device } => vec![("device", num(device))],
            TraceEvent::Capability { device, weight } => {
                vec![("device", num(device)), ("weight", Json::Num(weight))]
            }
        }
    }

    /// Rebuild an event from its `args` object. The inverse of
    /// [`TraceEvent::args`]; unknown kinds and missing fields are errors.
    fn from_args(kind: &str, args: &BTreeMap<String, Json>) -> Result<TraceEvent, String> {
        let f = |k: &str| -> Result<f64, String> {
            args.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("event `{kind}` missing numeric args.{k}"))
        };
        let u = |k: &str| -> Result<u64, String> { f(k).map(|v| v as u64) };
        let b = |k: &str| -> Result<bool, String> {
            args.get(k)
                .and_then(Json::as_bool)
                .ok_or_else(|| format!("event `{kind}` missing bool args.{k}"))
        };
        Ok(match kind {
            "release" => TraceEvent::FrameRelease {
                frame: u("frame")?,
                origin: u("origin")?,
            },
            "shed" => TraceEvent::FrameShed {
                origin: u("origin")?,
                class: u("class")?,
            },
            "deferred" => TraceEvent::FrameDeferred {
                origin: u("origin")?,
                depth: u("depth")?,
            },
            "sched" => TraceEvent::SchedDecision {
                frame: u("frame")?,
                node: u("node")?,
                dev: match args.get("dev") {
                    Some(Json::Null) | None => None,
                    Some(v) => Some(v.as_f64().ok_or("args.dev must be a number or null")? as u64),
                },
                comm_s: f("comm_s")?,
                hops: u("hops")?,
                calls: u("calls")?,
                escalated: b("escalated")?,
                degraded: b("degraded")?,
            },
            "sched_wall" => TraceEvent::SchedWall {
                compute_s: f("compute_s")?,
            },
            "xfer" => TraceEvent::Transfer {
                frame: u("frame")?,
                node: u("node")?,
                from: u("from")?,
                to: u("to")?,
                bytes: f("bytes")?,
                delay_s: f("delay_s")?,
            },
            "exec" => TraceEvent::ExecSpan {
                frame: u("frame")?,
                node: u("node")?,
                device: u("device")?,
                pu: u("pu")?,
                start_t: f("start_t")?,
            },
            "queued" => TraceEvent::Queued {
                frame: u("frame")?,
                node: u("node")?,
                device: u("device")?,
                pu: u("pu")?,
            },
            "frame" => TraceEvent::FrameComplete {
                frame: u("frame")?,
                origin: u("origin")?,
                release_t: f("release_t")?,
                latency_s: f("latency_s")?,
                compute_s: f("compute_s")?,
                qos_ok: b("qos_ok")?,
                degraded: b("degraded")?,
            },
            "handoff_send" => TraceEvent::HandoffSend {
                frame: u("frame")?,
                node: u("node")?,
                from_domain: u("from_domain")?,
                to_domain: u("to_domain")?,
                cross_s: f("cross_s")?,
            },
            "handoff_recv" => TraceEvent::HandoffRecv {
                from_domain: u("from_domain")?,
                to_domain: u("to_domain")?,
            },
            "remote_done" => TraceEvent::RemoteDone {
                frame: u("frame")?,
                node: u("node")?,
                cross_s: f("cross_s")?,
            },
            "barrier" => TraceEvent::Barrier {
                window_end: f("window_end")?,
                delivered: u("delivered")?,
            },
            "join" => TraceEvent::Join {
                device: u("device")?,
            },
            "leave" => TraceEvent::Leave {
                device: u("device")?,
                failure: b("failure")?,
            },
            "rereg" => TraceEvent::ReRegister {
                device: u("device")?,
            },
            "drain_escalate" => TraceEvent::DrainEscalate {
                device: u("device")?,
            },
            "capability" => TraceEvent::Capability {
                device: u("device")?,
                weight: f("weight")?,
            },
            other => return Err(format!("unknown trace event kind `{other}`")),
        })
    }
}

// ---------------------------------------------------------------------------
// the recorder
// ---------------------------------------------------------------------------

/// One time-stamped event in a shard's buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// simulated seconds
    pub t: f64,
    pub ev: TraceEvent,
}

/// Per-shard append-only event recorder. Lives inside the engine state;
/// when disabled, [`Tracer::emit`] is one branch and the event closure is
/// never evaluated. The legacy `HEYE_TRACE_ASSIGN` / `HEYE_TRACE_XFER`
/// stderr echoes ride this seam as cached flags (resolved once via
/// `util::env_flag`), independent of whether recording is on.
#[derive(Debug, Default)]
pub struct Tracer {
    enabled: bool,
    wall: bool,
    echo_assign: bool,
    echo_xfer: bool,
    records: Vec<TraceRecord>,
}

impl Tracer {
    /// A disabled tracer (the engine-state default).
    pub fn off() -> Tracer {
        Tracer::default()
    }

    pub fn new(spec: TraceSpec) -> Tracer {
        Tracer {
            enabled: spec.enabled,
            wall: spec.enabled && spec.wall,
            echo_assign: crate::util::env_flag("HEYE_TRACE_ASSIGN"),
            echo_xfer: crate::util::env_flag("HEYE_TRACE_XFER"),
            records: Vec::new(),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Is the wall-clock channel on?
    #[inline]
    pub fn wall(&self) -> bool {
        self.wall
    }

    /// Legacy `HEYE_TRACE_ASSIGN` stderr echo requested?
    #[inline]
    pub fn echo_assign(&self) -> bool {
        self.echo_assign
    }

    /// Legacy `HEYE_TRACE_XFER` stderr echo requested?
    #[inline]
    pub fn echo_xfer(&self) -> bool {
        self.echo_xfer
    }

    /// Record an event at simulated time `t`. The closure is only called
    /// when tracing is enabled.
    #[inline]
    pub fn emit(&mut self, t: f64, ev: impl FnOnce() -> TraceEvent) {
        if self.enabled {
            self.records.push(TraceRecord { t, ev: ev() });
        }
    }

    /// Drain the buffer (for [`Trace::assemble`]).
    pub fn take(&mut self) -> Vec<TraceRecord> {
        std::mem::take(&mut self.records)
    }
}

// ---------------------------------------------------------------------------
// the merged trace
// ---------------------------------------------------------------------------

/// Run-level metadata carried in the trace file's `"heye"` object.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceMeta {
    pub scheduler: String,
    pub horizon_s: f64,
    pub seed: u64,
    /// shard count of the engine that ran: `0` = monolithic, `n >= 1` =
    /// sharded over `n` domains. Overhead reconstruction needs this to
    /// replay the engine's exact float-accumulation order.
    pub shards: u64,
    /// wall-clock channel recorded?
    pub wall: bool,
}

/// A record tagged with its origin shard and per-shard sequence number —
/// the deterministic merge key.
#[derive(Debug, Clone, PartialEq)]
pub struct TaggedRecord {
    pub shard: u64,
    pub seq: u64,
    pub t: f64,
    pub ev: TraceEvent,
}

/// A finished run's merged trace. Records are stored in `(shard, seq)`
/// order — per-shard emission order, shards concatenated in id order —
/// which is the order every reconstruction replays; the Chrome export
/// re-sorts a view by `(t, shard, seq)` for display.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub meta: TraceMeta,
    pub records: Vec<TaggedRecord>,
}

impl Trace {
    /// Merge per-shard buffers (index = shard id; the monolithic engine
    /// passes one buffer) into a trace. Deterministic: the output depends
    /// only on buffer contents, which each shard's event loop fills
    /// identically for any worker count.
    pub fn assemble(meta: TraceMeta, buffers: Vec<Vec<TraceRecord>>) -> Trace {
        let mut records = Vec::new();
        for (shard, buf) in buffers.into_iter().enumerate() {
            for (seq, r) in buf.into_iter().enumerate() {
                records.push(TaggedRecord {
                    shard: shard as u64,
                    seq: seq as u64,
                    t: r.t,
                    ev: r.ev,
                });
            }
        }
        Trace { meta, records }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    // ----- Chrome trace-event export ------------------------------------

    /// Export as a Chrome trace-event JSON document (see the module docs
    /// for the schema). `names` optionally maps device ids to display
    /// names for the thread tracks; it does not affect `args` payloads.
    pub fn to_chrome_json(&self, names: Option<&BTreeMap<u64, String>>) -> Json {
        let mut events: Vec<Json> = Vec::new();
        // metadata: name one process per shard, one thread per device
        let shards: BTreeSet<u64> = self.records.iter().map(|r| r.shard).collect();
        let threads: BTreeSet<(u64, u64)> =
            self.records.iter().map(|r| (r.shard, r.ev.tid())).collect();
        for &pid in &shards {
            events.push(Json::obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("process_name".into())),
                ("pid", Json::Num(pid as f64)),
                ("tid", Json::Num(0.0)),
                (
                    "args",
                    Json::obj(vec![("name", Json::Str(format!("domain {pid}")))]),
                ),
            ]));
        }
        for &(pid, tid) in &threads {
            let label = if tid == ORC_TID {
                "orchestrator".to_string()
            } else {
                names
                    .and_then(|m| m.get(&tid).cloned())
                    .unwrap_or_else(|| format!("dev {tid}"))
            };
            events.push(Json::obj(vec![
                ("ph", Json::Str("M".into())),
                ("name", Json::Str("thread_name".into())),
                ("pid", Json::Num(pid as f64)),
                ("tid", Json::Num(tid as f64)),
                ("args", Json::obj(vec![("name", Json::Str(label))])),
            ]));
        }
        // display order: by time, ties broken by the merge key
        let mut order: Vec<&TaggedRecord> = self.records.iter().collect();
        order.sort_by(|a, b| {
            a.t.total_cmp(&b.t)
                .then(a.shard.cmp(&b.shard))
                .then(a.seq.cmp(&b.seq))
        });
        for r in order {
            let mut args = vec![
                ("kind", Json::Str(r.ev.kind().into())),
                ("t", Json::Num(r.t)),
                ("shard", Json::Num(r.shard as f64)),
                ("seq", Json::Num(r.seq as f64)),
            ];
            args.extend(r.ev.args());
            // duration events: exec spans start at start_t, transfers at t
            let (ph, ts, dur) = match r.ev {
                TraceEvent::ExecSpan { start_t, .. } => ("X", start_t, Some(r.t - start_t)),
                TraceEvent::Transfer { delay_s, .. } => ("X", r.t, Some(delay_s)),
                _ => ("i", r.t, None),
            };
            let mut ev = vec![
                ("ph", Json::Str(ph.into())),
                ("name", Json::Str(r.ev.kind().into())),
                ("ts", Json::Num(ts * 1e6)),
                ("pid", Json::Num(r.shard as f64)),
                ("tid", Json::Num(r.ev.tid() as f64)),
            ];
            if let Some(d) = dur {
                ev.push(("dur", Json::Num(d * 1e6)));
            }
            if ph == "i" {
                // instant scope: thread
                ev.push(("s", Json::Str("t".into())));
            }
            ev.push(("args", Json::obj(args)));
            events.push(Json::obj(ev));
        }
        Json::obj(vec![
            ("displayTimeUnit", Json::Str("ms".into())),
            (
                "heye",
                Json::obj(vec![
                    ("schema", Json::Num(SCHEMA_VERSION as f64)),
                    ("scheduler", Json::Str(self.meta.scheduler.clone())),
                    ("horizon_s", Json::Num(self.meta.horizon_s)),
                    ("seed", Json::Num(self.meta.seed as f64)),
                    ("shards", Json::Num(self.meta.shards as f64)),
                    ("wall", Json::Bool(self.meta.wall)),
                ]),
            ),
            ("traceEvents", Json::Arr(events)),
        ])
    }

    /// Parse (and schema-validate) a Chrome trace-event document produced
    /// by [`Trace::to_chrome_json`]. Full-precision values are read from
    /// `args`; the `ts`/`dur` microseconds are display-only and ignored.
    pub fn from_json(doc: &Json) -> Result<Trace, String> {
        let heye = doc
            .get("heye")
            .ok_or("not an heye trace: missing top-level \"heye\" object")?;
        let schema = heye.get("schema").and_then(Json::as_u64).unwrap_or(0);
        if schema != SCHEMA_VERSION {
            return Err(format!(
                "unsupported trace schema {schema} (this build reads {SCHEMA_VERSION})"
            ));
        }
        let meta = TraceMeta {
            scheduler: heye
                .get("scheduler")
                .and_then(Json::as_str)
                .ok_or("heye.scheduler missing")?
                .to_string(),
            horizon_s: heye
                .get("horizon_s")
                .and_then(Json::as_f64)
                .ok_or("heye.horizon_s missing")?,
            seed: heye.get("seed").and_then(Json::as_u64).unwrap_or(0),
            shards: heye
                .get("shards")
                .and_then(Json::as_u64)
                .ok_or("heye.shards missing")?,
            wall: heye.get("wall").and_then(Json::as_bool).unwrap_or(false),
        };
        let events = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .ok_or("missing \"traceEvents\" array")?;
        let mut records = Vec::new();
        for (i, e) in events.iter().enumerate() {
            let ph = e
                .get("ph")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("traceEvents[{i}]: missing ph"))?;
            match ph {
                "M" => continue, // metadata: display-only
                "X" | "i" => {}
                other => return Err(format!("traceEvents[{i}]: unsupported ph `{other}`")),
            }
            for key in ["name", "ts", "pid", "tid"] {
                if e.get(key).is_none() {
                    return Err(format!("traceEvents[{i}]: missing {key}"));
                }
            }
            let args = e
                .get("args")
                .and_then(Json::as_obj)
                .ok_or_else(|| format!("traceEvents[{i}]: missing args object"))?;
            let kind = args
                .get("kind")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("traceEvents[{i}]: missing args.kind"))?;
            let t = args
                .get("t")
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("traceEvents[{i}]: missing args.t"))?;
            let shard = args
                .get("shard")
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("traceEvents[{i}]: missing args.shard"))?;
            let seq = args
                .get("seq")
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("traceEvents[{i}]: missing args.seq"))?;
            let ev = TraceEvent::from_args(kind, args)
                .map_err(|m| format!("traceEvents[{i}]: {m}"))?;
            records.push(TaggedRecord { shard, seq, t, ev });
        }
        // restore storage order and check the merge key is sound
        records.sort_by(|a, b| a.shard.cmp(&b.shard).then(a.seq.cmp(&b.seq)));
        for w in records.windows(2) {
            if w[0].shard == w[1].shard && w[0].seq == w[1].seq {
                return Err(format!(
                    "duplicate (shard, seq) = ({}, {})",
                    w[0].shard, w[0].seq
                ));
            }
        }
        Ok(Trace { meta, records })
    }

    // ----- overhead reconstruction --------------------------------------

    /// Re-derive the engine's scheduling-overhead accounting from the
    /// trace alone — the `heye trace overhead` report. Floats are
    /// accumulated in the engine's exact order (per-shard sequence order,
    /// then shard-order merge; frame compute in completion-report order),
    /// so the totals match the run's `RunMetrics` bit for bit.
    pub fn overhead_report(&self) -> OverheadReport {
        let mut comm = 0.0f64;
        let mut wall = 0.0f64;
        let mut hops = 0u64;
        let mut calls = 0u64;
        let mut decisions = 0u64;
        let mut escalations = 0u64;
        let mut idx = 0;
        while idx < self.records.len() {
            let shard = self.records[idx].shard;
            // per-shard subtotal in seq order, folded in shard order —
            // mirrors the engine's per-shard accumulators and the sharded
            // merge (a monolithic run is the single-shard case)
            let mut sub_comm = 0.0f64;
            let mut sub_wall = 0.0f64;
            while idx < self.records.len() && self.records[idx].shard == shard {
                match self.records[idx].ev {
                    TraceEvent::SchedDecision {
                        comm_s,
                        hops: h,
                        calls: c,
                        escalated,
                        ..
                    } => {
                        sub_comm += comm_s;
                        hops += h;
                        calls += c;
                        decisions += 1;
                        escalations += escalated as u64;
                    }
                    TraceEvent::SchedWall { compute_s } => sub_wall += compute_s,
                    _ => {}
                }
                idx += 1;
            }
            comm += sub_comm;
            wall += sub_wall;
        }
        // frame compute in the order RunMetrics reports frames: push order
        // for the monolithic engine, the sharded merge's
        // (finish, release, origin) stable sort otherwise
        let mut frames: Vec<(f64, f64, u64, f64, bool)> = self
            .records
            .iter()
            .filter_map(|r| match r.ev {
                TraceEvent::FrameComplete {
                    origin,
                    release_t,
                    compute_s,
                    qos_ok,
                    ..
                } => Some((r.t, release_t, origin, compute_s, qos_ok)),
                _ => None,
            })
            .collect();
        if self.meta.shards >= 1 {
            frames.sort_by(|a, b| {
                a.0.total_cmp(&b.0)
                    .then(a.1.total_cmp(&b.1))
                    .then(a.2.cmp(&b.2))
            });
        }
        let frame_compute: f64 = frames.iter().map(|f| f.3).sum();
        let qos_ok = frames.iter().filter(|f| f.4).count() as u64;
        OverheadReport {
            scheduler: self.meta.scheduler.clone(),
            decisions,
            escalations,
            sched_comm_s: comm,
            sched_compute_s: if self.meta.wall { Some(wall) } else { None },
            sched_hops: hops,
            traverser_calls: calls,
            frames: frames.len() as u64,
            frames_qos_ok: qos_ok,
            frame_compute_s: frame_compute,
        }
    }

    // ----- utilization --------------------------------------------------

    /// Per-domain busy seconds over `buckets` equal slices of the horizon,
    /// smeared from the execution spans: the utilization timeline behind
    /// the metrics snapshot.
    pub fn utilization(&self, buckets: usize) -> BTreeMap<u64, Vec<f64>> {
        let n = buckets.max(1);
        let width = self.meta.horizon_s / n as f64;
        let mut by_domain: BTreeMap<u64, Vec<f64>> = BTreeMap::new();
        if !(width > 0.0) {
            return by_domain;
        }
        for r in &self.records {
            let TraceEvent::ExecSpan { start_t, .. } = r.ev else {
                continue;
            };
            let (a, b) = (start_t.max(0.0), r.t.min(self.meta.horizon_s));
            if !(b > a) {
                continue;
            }
            let slots = by_domain.entry(r.shard).or_insert_with(|| vec![0.0; n]);
            let first = ((a / width).floor() as usize).min(n - 1);
            let last = ((b / width).ceil() as usize).clamp(first + 1, n);
            for (i, slot) in slots.iter_mut().enumerate().take(last).skip(first) {
                let lo = i as f64 * width;
                let hi = lo + width;
                let overlap = (b.min(hi) - a.max(lo)).max(0.0);
                *slot += overlap;
            }
        }
        by_domain
    }

    /// The utilization timeline as JSON: `[{domain, bucket_s, busy_s:
    /// [...]}, ...]`.
    pub fn utilization_json(&self, buckets: usize) -> Json {
        let width = self.meta.horizon_s / buckets.max(1) as f64;
        Json::Arr(
            self.utilization(buckets)
                .into_iter()
                .map(|(d, busy)| {
                    Json::obj(vec![
                        ("domain", Json::Num(d as f64)),
                        ("bucket_s", Json::Num(width)),
                        ("busy_s", Json::Arr(busy.into_iter().map(Json::Num).collect())),
                    ])
                })
                .collect(),
        )
    }
}

// ---------------------------------------------------------------------------
// the overhead budget report
// ---------------------------------------------------------------------------

/// Scheduling-overhead accounting reconstructed from a trace — the
/// `heye trace overhead` budget report reproducing the paper's <2% figure.
#[derive(Debug, Clone, PartialEq)]
pub struct OverheadReport {
    pub scheduler: String,
    pub decisions: u64,
    pub escalations: u64,
    /// modeled scheduler communication seconds (deterministic channel)
    pub sched_comm_s: f64,
    /// measured constraint-check wall seconds (`None` when the trace was
    /// recorded without the wall channel)
    pub sched_compute_s: Option<f64>,
    pub sched_hops: u64,
    pub traverser_calls: u64,
    pub frames: u64,
    pub frames_qos_ok: u64,
    /// standalone compute seconds of the completed frames — the
    /// denominator of the paper's Fig. 14 overhead ratio
    pub frame_compute_s: f64,
}

impl OverheadReport {
    /// The Fig. 14 metric: total scheduling overhead over frame compute —
    /// the same expression `RunMetrics::overhead_ratio` evaluates.
    pub fn overhead_ratio(&self) -> f64 {
        if self.frame_compute_s <= 0.0 {
            return 0.0;
        }
        (self.sched_comm_s + self.sched_compute_s.unwrap_or(0.0)) / self.frame_compute_s
    }

    /// Share of the overhead that is modeled communication (vs measured
    /// compute); `1.0` when the wall channel is off.
    pub fn comm_fraction(&self) -> f64 {
        let total = self.sched_comm_s + self.sched_compute_s.unwrap_or(0.0);
        if total <= 0.0 {
            return 0.0;
        }
        self.sched_comm_s / total
    }

    /// Does the ratio stay under `budget_pct` percent?
    pub fn within_budget(&self, budget_pct: f64) -> bool {
        self.overhead_ratio() * 100.0 <= budget_pct
    }
}

impl std::fmt::Display for OverheadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "== scheduling-overhead budget (from trace) ==")?;
        writeln!(f, "scheduler        {}", self.scheduler)?;
        writeln!(
            f,
            "decisions        {} ({} escalations)",
            self.decisions, self.escalations
        )?;
        writeln!(
            f,
            "sched comm       {:.3} ms ({} hops, {} traverser calls)",
            self.sched_comm_s * 1e3,
            self.sched_hops,
            self.traverser_calls
        )?;
        match self.sched_compute_s {
            Some(w) => writeln!(f, "sched compute    {:.3} ms (measured, wall channel)", w * 1e3)?,
            None => writeln!(
                f,
                "sched compute    not recorded (re-run with --trace-wall)"
            )?,
        }
        writeln!(
            f,
            "frame compute    {:.3} ms over {} frames ({} QoS-ok)",
            self.frame_compute_s * 1e3,
            self.frames,
            self.frames_qos_ok
        )?;
        write!(
            f,
            "overhead         {:.3}% of frame compute (comm fraction {:.0}%)",
            self.overhead_ratio() * 100.0,
            self.comm_fraction() * 100.0
        )
    }
}

// ---------------------------------------------------------------------------
// metrics registry
// ---------------------------------------------------------------------------

/// Named counters, gauges, and log-bucketed histograms snapshotted per run
/// — the aggregate view a trace distills into (`heye run --trace-metrics`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, LogHistogram>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Record `v` into the named latency-shaped histogram.
    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms
            .entry(name.to_string())
            .or_insert_with(LogHistogram::latency)
            .push(v);
    }

    /// Fold another registry in: counters and histograms add, gauges take
    /// the other side's value.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.histograms {
            self.histograms
                .entry(k.clone())
                .or_insert_with(LogHistogram::latency)
                .merge(h);
        }
    }

    /// Distill a trace into the standard per-run snapshot: event counters,
    /// latency/transfer/span histograms, and the overhead gauges.
    pub fn from_trace(tr: &Trace) -> MetricsRegistry {
        let mut reg = MetricsRegistry::new();
        for r in &tr.records {
            reg.inc(&format!("events.{}", r.ev.kind()), 1);
            match r.ev {
                TraceEvent::FrameComplete {
                    latency_s,
                    compute_s,
                    qos_ok,
                    ..
                } => {
                    reg.observe("frame.latency_s", latency_s);
                    reg.observe("frame.compute_s", compute_s);
                    if !qos_ok {
                        reg.inc("frames.qos_miss", 1);
                    }
                }
                TraceEvent::Transfer { delay_s, bytes, .. } => {
                    reg.observe("xfer.delay_s", delay_s);
                    reg.observe("xfer.bytes", bytes);
                }
                TraceEvent::ExecSpan { start_t, .. } => {
                    reg.observe("exec.span_s", r.t - start_t);
                }
                TraceEvent::SchedDecision { comm_s, .. } => {
                    reg.observe("sched.comm_s", comm_s);
                }
                TraceEvent::SchedWall { compute_s } => {
                    reg.observe("sched.compute_s", compute_s);
                }
                _ => {}
            }
        }
        let report = tr.overhead_report();
        reg.gauge("sched.overhead_ratio", report.overhead_ratio());
        reg.gauge("sched.comm_fraction", report.comm_fraction());
        reg.gauge("frames.completed", report.frames as f64);
        reg
    }

    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v)))
                .collect(),
        );
        let histograms = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    let buckets = Json::Arr(
                        h.buckets()
                            .map(|(lo, hi, c)| {
                                Json::Arr(vec![
                                    Json::Num(lo),
                                    Json::Num(hi),
                                    Json::Num(c as f64),
                                ])
                            })
                            .collect(),
                    );
                    let quant = |q: f64| {
                        let v = h.quantile(q);
                        if v.is_finite() {
                            Json::Num(v)
                        } else {
                            Json::Null
                        }
                    };
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::Num(h.count() as f64)),
                            ("underflow", Json::Num(h.underflow() as f64)),
                            ("mean", Json::Num(h.mean())),
                            ("p50", quant(0.5)),
                            ("p95", quant(0.95)),
                            ("p99", quant(0.99)),
                            ("buckets", buckets),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histograms),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(shards: u64, wall: bool) -> TraceMeta {
        TraceMeta {
            scheduler: "heye".into(),
            horizon_s: 1.0,
            seed: 7,
            shards,
            wall,
        }
    }

    #[test]
    fn disabled_tracer_records_nothing_and_skips_the_closure() {
        let mut tr = Tracer::off();
        let mut evaluated = false;
        tr.emit(0.1, || {
            evaluated = true;
            TraceEvent::Join { device: 1 }
        });
        assert!(!evaluated, "event closure must not run when disabled");
        assert!(tr.take().is_empty());
    }

    #[test]
    fn assemble_tags_records_with_shard_and_seq() {
        let buf0 = vec![TraceRecord {
            t: 0.2,
            ev: TraceEvent::Join { device: 1 },
        }];
        let buf1 = vec![
            TraceRecord {
                t: 0.1,
                ev: TraceEvent::Join { device: 2 },
            },
            TraceRecord {
                t: 0.3,
                ev: TraceEvent::Leave {
                    device: 2,
                    failure: true,
                },
            },
        ];
        let tr = Trace::assemble(meta(2, false), vec![buf0, buf1]);
        let tags: Vec<(u64, u64)> = tr.records.iter().map(|r| (r.shard, r.seq)).collect();
        assert_eq!(tags, vec![(0, 0), (1, 0), (1, 1)]);
    }

    #[test]
    fn chrome_roundtrip_preserves_records_and_meta() {
        let buf = vec![
            TraceRecord {
                t: 0.25,
                ev: TraceEvent::SchedDecision {
                    frame: 3,
                    node: 1,
                    dev: Some(4),
                    comm_s: 0.001234567890123,
                    hops: 2,
                    calls: 17,
                    escalated: false,
                    degraded: true,
                },
            },
            TraceRecord {
                t: 0.5,
                ev: TraceEvent::ExecSpan {
                    frame: 3,
                    node: 1,
                    device: 4,
                    pu: 9,
                    start_t: 0.26,
                },
            },
            TraceRecord {
                t: 0.5,
                ev: TraceEvent::FrameComplete {
                    frame: 3,
                    origin: 0,
                    release_t: 0.25,
                    latency_s: 0.25,
                    compute_s: 0.2,
                    qos_ok: true,
                    degraded: false,
                },
            },
        ];
        let tr = Trace::assemble(meta(0, false), vec![buf]);
        let doc = tr.to_chrome_json(None);
        let text = doc.to_string();
        let parsed = Trace::from_json(&Json::parse(&text).expect("emitted JSON parses"))
            .expect("round-trips");
        assert_eq!(parsed, tr, "records and meta survive bit-for-bit");
        // and serialization is deterministic
        assert_eq!(parsed.to_chrome_json(None).to_string(), text);
    }

    #[test]
    fn from_json_rejects_schema_and_shape_errors() {
        assert!(Trace::from_json(&Json::parse("{}").unwrap())
            .unwrap_err()
            .contains("heye"));
        let bad_schema = r#"{"heye": {"schema": 99}, "traceEvents": []}"#;
        assert!(Trace::from_json(&Json::parse(bad_schema).unwrap())
            .unwrap_err()
            .contains("schema"));
        let bad_event = r#"{
          "heye": {"schema": 1, "scheduler": "x", "horizon_s": 1, "seed": 0,
                   "shards": 0, "wall": false},
          "traceEvents": [{"ph": "i", "name": "y", "ts": 0, "pid": 0,
                           "tid": 0, "args": {"kind": "nope", "t": 0,
                           "shard": 0, "seq": 0}}]
        }"#;
        assert!(Trace::from_json(&Json::parse(bad_event).unwrap())
            .unwrap_err()
            .contains("unknown trace event kind"));
    }

    #[test]
    fn overhead_report_accumulates_per_shard_then_merges() {
        let decision = |comm_s: f64| TraceEvent::SchedDecision {
            frame: 0,
            node: 0,
            dev: Some(1),
            comm_s,
            hops: 1,
            calls: 3,
            escalated: false,
            degraded: false,
        };
        let frame = |compute_s: f64| TraceEvent::FrameComplete {
            frame: 0,
            origin: 0,
            release_t: 0.0,
            latency_s: 0.1,
            compute_s,
            qos_ok: true,
            degraded: false,
        };
        let buf0 = vec![
            TraceRecord {
                t: 0.1,
                ev: decision(0.001),
            },
            TraceRecord {
                t: 0.2,
                ev: frame(0.05),
            },
        ];
        let buf1 = vec![
            TraceRecord {
                t: 0.15,
                ev: decision(0.002),
            },
            TraceRecord {
                t: 0.18,
                ev: frame(0.07),
            },
        ];
        let tr = Trace::assemble(meta(2, false), vec![buf0, buf1]);
        let rep = tr.overhead_report();
        assert_eq!(rep.decisions, 2);
        assert_eq!(rep.sched_hops, 2);
        assert_eq!(rep.traverser_calls, 6);
        assert_eq!(rep.frames, 2);
        assert!((rep.sched_comm_s - 0.003).abs() < 1e-15);
        assert!((rep.frame_compute_s - 0.12).abs() < 1e-15);
        assert!(rep.sched_compute_s.is_none(), "wall channel off");
        assert!((rep.overhead_ratio() - 0.003 / 0.12).abs() < 1e-12);
        assert!(rep.within_budget(2.51) && !rep.within_budget(2.49));
    }

    #[test]
    fn utilization_smears_spans_over_buckets() {
        let buf = vec![TraceRecord {
            t: 0.3,
            ev: TraceEvent::ExecSpan {
                frame: 0,
                node: 0,
                device: 1,
                pu: 0,
                start_t: 0.1,
            },
        }];
        let tr = Trace::assemble(meta(0, false), vec![buf]);
        let util = tr.utilization(10); // 0.1 s buckets over 1 s
        let busy = &util[&0];
        assert!((busy[1] - 0.1).abs() < 1e-12);
        assert!((busy[2] - 0.1).abs() < 1e-12);
        assert!((busy.iter().sum::<f64>() - 0.2).abs() < 1e-12);
        assert_eq!(busy[0], 0.0);
    }

    #[test]
    fn registry_distills_counters_histograms_and_gauges() {
        let buf = vec![
            TraceRecord {
                t: 0.1,
                ev: TraceEvent::SchedDecision {
                    frame: 0,
                    node: 0,
                    dev: Some(1),
                    comm_s: 0.001,
                    hops: 1,
                    calls: 2,
                    escalated: true,
                    degraded: false,
                },
            },
            TraceRecord {
                t: 0.2,
                ev: TraceEvent::FrameComplete {
                    frame: 0,
                    origin: 0,
                    release_t: 0.1,
                    latency_s: 0.1,
                    compute_s: 0.08,
                    qos_ok: false,
                    degraded: false,
                },
            },
        ];
        let tr = Trace::assemble(meta(0, false), vec![buf]);
        let reg = MetricsRegistry::from_trace(&tr);
        assert_eq!(reg.counters["events.sched"], 1);
        assert_eq!(reg.counters["frames.qos_miss"], 1);
        assert_eq!(reg.histograms["frame.latency_s"].count(), 1);
        assert!(reg.gauges["sched.overhead_ratio"] > 0.0);
        // snapshot JSON parses back
        let text = reg.to_json().to_string();
        assert!(Json::parse(&text).is_ok());
        // merge: counters add
        let mut twice = reg.clone();
        twice.merge(&reg);
        assert_eq!(twice.counters["events.sched"], 2);
        assert_eq!(twice.histograms["frame.latency_s"].count(), 2);
    }
}
