//! Mining smart-drill-bit driver (§4.2) — the throughput-oriented example.
//!
//! 1. Executes the three real ML classifiers (SVM / KNN / MLP artifacts)
//!    on a synthetic force-sensor window through PJRT (when the `pjrt`
//!    feature and artifacts are available) and reports their per-window
//!    host latencies and rock-class votes.
//! 2. Runs the collaborative edge+server mining workload through a
//!    [`heye::platform::Session`] for H-EYE and every baseline, reporting
//!    completion latency and QoS — the Fig. 10a story.
//!
//! ```text
//! cargo run --release --example mining_drill [-- --sensors 20 --horizon 1.0]
//! ```

use heye::platform::{Platform, WorkloadSpec};
use heye::runtime::Runtime;
use heye::sim::SimConfig;
use heye::task::workloads::MINING_DEADLINE_S;
use heye::telemetry;
use heye::util::cli::Args;
use heye::util::error::Result;

fn main() -> Result<()> {
    let args = Args::from_env();
    let sensors = args.get_usize("sensors", 20);
    let horizon = args.get_f64("horizon", 1.0);

    // --- real classifier executions (PJRT, when available) ----------------
    match Runtime::open("artifacts") {
        Ok(rt) => classify_window(rt)?,
        Err(e) => println!("(skipping real classifier executions: {e})"),
    }

    // --- collaborative processing at scale --------------------------------
    println!(
        "\n{sensors} sensors @ 10 Hz across the paper testbed ({}s horizon, {} ms deadline):",
        horizon,
        MINING_DEADLINE_S * 1e3
    );
    let platform = Platform::builder().paper_vr().build()?;
    telemetry::compare(
        &platform,
        WorkloadSpec::Mining { sensors, hz: 10.0 },
        &["heye", "ace", "lats"],
        &SimConfig::default().horizon(horizon).seed(42),
    )?;

    // --- the Fig. 10a sweep: how many sensors fit 100 ms? -----------------
    println!("\nmax sensors within 100 ms on Orin Nano + server-1 (Fig. 10a):");
    println!(
        "{:<8} {:>14} {:>14} {:>10}",
        "sensors", "heye (ms)", "ace (ms)", "winner-ok"
    );
    let pair = Platform::builder().validation_pair().build()?;
    for n in [10, 20, 30, 40] {
        let mut lat = Vec::new();
        for name in ["heye", "ace"] {
            let report = pair
                .session(WorkloadSpec::MiningBurst { origin: 0, n })
                .scheduler(name)
                .config(SimConfig::default().horizon(3.0).seed(7).noise(0.0))
                .run()?;
            let worst = report
                .metrics
                .frames
                .iter()
                .map(|f| f.latency_s)
                .fold(0.0f64, f64::max);
            lat.push(worst);
        }
        println!(
            "{:<8} {:>14.1} {:>14.1} {:>10}",
            n,
            lat[0] * 1e3,
            lat[1] * 1e3,
            if lat[0] <= MINING_DEADLINE_S { "yes" } else { "no" }
        );
    }
    Ok(())
}

/// Run the three mining classifiers on one synthetic force window.
fn classify_window(mut rt: Runtime) -> Result<()> {
    println!("PJRT platform: {}", rt.platform());
    println!("\nreal sensor-window classification (batch of 32 windows):");
    // a synthetic force window: a slow ramp + tool-chatter oscillation
    let window: Vec<f32> = (0..64)
        .map(|i| 0.01 * i as f32 + 0.3 * ((i as f32) * 0.9).sin())
        .collect();
    println!("{:<14} {:>10} {:>16}", "classifier", "host (ms)", "top class (w0)");
    for name in ["mining_svm", "mining_knn", "mining_mlp"] {
        let m = rt.load(name)?;
        let input = m.input_from(0, &window)?;
        let (_, _) = m.execute(&[m.input_from(0, &window)?])?; // warm
        let (outs, dt) = m.execute(&[input])?;
        let scores: Vec<f32> = outs[0].to_vec()?;
        // scores are (32, 8); argmax of the first window's 8 class scores
        let first = &scores[..8];
        let top = first
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        println!("{:<14} {:>10.3} {:>16}", name, dt * 1e3, top);
    }
    Ok(())
}
