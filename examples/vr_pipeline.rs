//! End-to-end VR driver — the repo's full-stack proof.
//!
//! All three layers compose here:
//! 1. **L1/L2 (build-time)**: `make artifacts` lowered the Pallas-backed
//!    JAX models to `artifacts/*.hlo.txt`.
//! 2. **Runtime** (needs the `pjrt` feature): this binary compiles them on
//!    the PJRT CPU client and *really executes* the whole VR frame
//!    pipeline — pose-predict → render → encode → decode → reproject →
//!    display — chaining real tensors between stages, plus a host profile
//!    that anchors the simulator's standalone latencies to measured kernel
//!    times. Without the feature this section degrades gracefully.
//! 3. **L3 (coordinator)**: a [`heye::platform::Session`] places every
//!    task of the 5-edge/3-server VR workload and reports the
//!    Fig.-11a-style breakdown.
//!
//! ```text
//! cargo run --release --example vr_pipeline [-- --frames 30 --horizon 2.0]
//! ```

use heye::platform::{Platform, WorkloadSpec};
use heye::runtime::{HostProfiler, Runtime};
use heye::sim::SimConfig;
use heye::task::workloads::target_fps;
use heye::util::cli::Args;
use heye::util::error::Result;
use heye::util::stats::Samples;

fn main() -> Result<()> {
    let args = Args::from_env();
    let frames = args.get_usize("frames", 30);
    let horizon = args.get_f64("horizon", 2.0);

    // --- runtime: real PJRT frames, when the artifacts + feature exist ---
    match Runtime::open("artifacts") {
        Ok(rt) => real_frames(rt, frames)?,
        Err(e) => println!("(skipping real PJRT frames: {e})"),
    }

    // --- the coordinated system, through the facade ----------------------
    let platform = Platform::builder().paper_vr().build()?;
    let report = platform
        .session(WorkloadSpec::Vr)
        .scheduler("heye")
        .config(SimConfig::default().horizon(horizon).seed(42))
        .run()?;

    println!();
    report.print_summary();
    report.print_breakdown("VR per-device breakdown (Fig. 11a view)");
    for r in &report.per_device() {
        println!(
            "  {:<10} achieved {:>5.1} FPS (target {:.0})",
            r.name,
            report.achieved_fps(r.device),
            target_fps(report.decs.device_model(r.device))
        );
    }
    Ok(())
}

/// Execute `frames` real VR frames through PJRT and print per-stage and
/// end-to-end host latencies plus the host profile.
fn real_frames(mut rt: Runtime, frames: usize) -> Result<()> {
    println!("PJRT platform: {}", rt.platform());

    // pose-predict produces the gaze; render/encode/decode/reproject chain
    // real (256, 256) tensors; display consumes the final frame.
    println!("\nexecuting {frames} real VR frames through PJRT:");
    let stage_names = [
        "vr_pose_predict",
        "vr_render",
        "vr_encode",
        "vr_decode",
        "vr_reproject",
        "vr_display",
    ];
    for s in &stage_names {
        rt.load(s)?; // compile before timing
    }
    let mut per_stage: Vec<Samples> = (0..stage_names.len()).map(|_| Samples::new()).collect();
    let mut e2e = Samples::new();
    let mut hidden: Vec<f32> = vec![0.0; 64];
    let mut checksum = 0.0f64;
    for f in 0..frames {
        let t0 = std::time::Instant::now();
        // pose predict: (feat, hidden) -> (pose, hidden')
        let m = rt.load("vr_pose_predict")?;
        let feat: Vec<f32> = (0..32).map(|i| ((f * 31 + i) % 17) as f32 * 0.1 - 0.8).collect();
        let inputs = vec![m.input_from(0, &feat)?, m.input_from(1, &hidden)?];
        let (outs, dt) = m.execute(&inputs)?;
        per_stage[0].push(dt * 1e3);
        let pose: Vec<f32> = outs[0].to_vec()?;
        hidden = outs[1].to_vec()?;
        // render <- scene seeded by the pose
        let m = rt.load("vr_render")?;
        let (outs, dt) = m.execute(&[m.input_from(0, &pose)?])?;
        per_stage[1].push(dt * 1e3);
        let mut frame: Vec<f32> = outs[0].to_vec()?;
        // encode -> decode -> reproject chain real tensors
        for (si, name) in ["vr_encode", "vr_decode", "vr_reproject"].iter().enumerate() {
            let m = rt.load(name)?;
            let (outs, dt) = m.execute(&[m.input_from(0, &frame)?])?;
            per_stage[2 + si].push(dt * 1e3);
            frame = outs[0].to_vec()?;
        }
        // display consumes the final frame
        let m = rt.load("vr_display")?;
        let (outs, dt) = m.execute(&[m.input_from(0, &frame)?])?;
        per_stage[5].push(dt * 1e3);
        let shown: Vec<f32> = outs[0].to_vec()?;
        checksum += shown.iter().map(|v| *v as f64).sum::<f64>();
        e2e.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    println!("{:<18} {:>10} {:>10}", "stage", "p50 (ms)", "p95 (ms)");
    for (i, s) in stage_names.iter().enumerate() {
        println!(
            "{:<18} {:>10.3} {:>10.3}",
            s,
            per_stage[i].percentile(50.0),
            per_stage[i].percentile(95.0)
        );
    }
    println!(
        "end-to-end host frame: p50 {:.3} ms, p95 {:.3} ms (checksum {:.3})",
        e2e.percentile(50.0),
        e2e.percentile(95.0),
        checksum
    );

    // --- host profile: the paper's empirical-profiling step ---------------
    // (HostProfiler::overlay can re-anchor the simulator's tables to these
    //  measurements — that models a host-CPU-speed testbed; here we keep
    //  the paper-calibrated Table-2 devices and report both.)
    let prof = HostProfiler::measure(&mut rt, 5)?;
    println!("\nhost profile (median ms per artifact):");
    for (name, s) in &prof.host_s {
        println!("  {:<18} {:>8.3}", name, s * 1e3);
    }
    Ok(())
}
