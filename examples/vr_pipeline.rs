//! End-to-end VR driver — the repo's full-stack proof.
//!
//! All three layers compose here:
//! 1. **L1/L2 (build-time)**: `make artifacts` lowered the Pallas-backed
//!    JAX models to `artifacts/*.hlo.txt`.
//! 2. **Runtime**: this binary compiles them on the PJRT CPU client and
//!    (a) *really executes* the whole VR frame pipeline — pose-predict →
//!    render → encode → decode → reproject → display — chaining real
//!    tensors between stages, and (b) measures a host profile that anchors
//!    the simulator's standalone latencies to measured kernel times.
//! 3. **L3 (coordinator)**: the Orchestrator places every task of the
//!    5-edge/3-server VR workload; the simulator executes the placements
//!    under the contention model and reports the Fig.-11a-style breakdown.
//!
//! ```text
//! cargo run --release --example vr_pipeline [-- --frames 30 --horizon 2.0]
//! ```

use anyhow::Result;

use heye::hwgraph::presets::{Decs, DecsSpec};
use heye::orchestrator::{Hierarchy, Orchestrator, Policy};
use heye::runtime::{HostProfiler, Runtime};
use heye::sim::{HeyeScheduler, SimConfig, Simulation, Workload};
use heye::telemetry;
use heye::util::cli::Args;
use heye::util::stats::Samples;

fn main() -> Result<()> {
    let args = Args::from_env();
    let frames = args.get_usize("frames", 30);
    let horizon = args.get_f64("horizon", 2.0);

    // --- runtime: load + compile the AOT artifacts -----------------------
    let mut rt = Runtime::open("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    // --- real end-to-end frames ------------------------------------------
    // pose-predict produces the gaze; render/encode/decode/reproject chain
    // real (256, 256) tensors; display consumes the final frame.
    println!("\nexecuting {frames} real VR frames through PJRT:");
    let stage_names = [
        "vr_pose_predict",
        "vr_render",
        "vr_encode",
        "vr_decode",
        "vr_reproject",
        "vr_display",
    ];
    for s in &stage_names {
        rt.load(s)?; // compile before timing
    }
    let mut per_stage: Vec<Samples> = (0..stage_names.len()).map(|_| Samples::new()).collect();
    let mut e2e = Samples::new();
    let mut hidden: Vec<f32> = vec![0.0; 64];
    let mut checksum = 0.0f64;
    for f in 0..frames {
        let t0 = std::time::Instant::now();
        // pose predict: (feat, hidden) -> (pose, hidden')
        let m = rt.load("vr_pose_predict")?;
        let feat: Vec<f32> = (0..32).map(|i| ((f * 31 + i) % 17) as f32 * 0.1 - 0.8).collect();
        let inputs = vec![m.input_from(0, &feat)?, m.input_from(1, &hidden)?];
        let (outs, dt) = m.execute(&inputs)?;
        per_stage[0].push(dt * 1e3);
        let pose: Vec<f32> = outs[0].to_vec()?;
        hidden = outs[1].to_vec()?;
        // render <- scene seeded by the pose
        let m = rt.load("vr_render")?;
        let (outs, dt) = m.execute(&[m.input_from(0, &pose)?])?;
        per_stage[1].push(dt * 1e3);
        let mut frame: Vec<f32> = outs[0].to_vec()?;
        // encode -> decode -> reproject chain real tensors
        for (si, name) in ["vr_encode", "vr_decode", "vr_reproject"].iter().enumerate() {
            let m = rt.load(name)?;
            let (outs, dt) = m.execute(&[m.input_from(0, &frame)?])?;
            per_stage[2 + si].push(dt * 1e3);
            frame = outs[0].to_vec()?;
        }
        // display consumes the final frame
        let m = rt.load("vr_display")?;
        let (outs, dt) = m.execute(&[m.input_from(0, &frame)?])?;
        per_stage[5].push(dt * 1e3);
        let shown: Vec<f32> = outs[0].to_vec()?;
        checksum += shown.iter().map(|v| *v as f64).sum::<f64>();
        e2e.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    println!("{:<18} {:>10} {:>10}", "stage", "p50 (ms)", "p95 (ms)");
    for (i, s) in stage_names.iter().enumerate() {
        println!(
            "{:<18} {:>10.3} {:>10.3}",
            s,
            per_stage[i].percentile(50.0),
            per_stage[i].percentile(95.0)
        );
    }
    println!(
        "end-to-end host frame: p50 {:.3} ms, p95 {:.3} ms (checksum {:.3})",
        e2e.percentile(50.0),
        e2e.percentile(95.0),
        checksum
    );

    // --- host profile: the paper's empirical-profiling step ---------------
    // (HostProfiler::overlay can re-anchor the simulator's tables to these
    //  measurements — that models a host-CPU-speed testbed; here we keep
    //  the paper-calibrated Table-2 devices and report both.)
    let prof = HostProfiler::measure(&mut rt, 5)?;
    println!("\nhost profile (median ms per artifact):");
    for (name, s) in &prof.host_s {
        println!("  {:<18} {:>8.3}", name, s * 1e3);
    }

    // --- the coordinated system ------------------------------------------
    let mut sim = Simulation::new(Decs::build(&DecsSpec::paper_vr()));
    let mut sched = HeyeScheduler::new(Orchestrator::new(
        Hierarchy::from_decs(&sim.decs),
        Policy::Hierarchical,
    ));
    let wl = Workload::vr(&sim.decs);
    let cfg = SimConfig::default().horizon(horizon).seed(42);
    let m = sim.run(&mut sched, wl, vec![], vec![], &cfg);

    println!();
    telemetry::summary_line("h-eye", &m);
    let rows = telemetry::per_device(&sim.decs, &m);
    telemetry::print_breakdown("VR per-device breakdown (Fig. 11a view)", &rows);
    for r in &rows {
        let fps = m.achieved_fps(r.device, horizon);
        println!(
            "  {:<10} achieved {:>5.1} FPS (target {:.0})",
            r.name,
            fps,
            heye::task::workloads::target_fps(sim.decs.device_model(r.device))
        );
    }
    Ok(())
}
