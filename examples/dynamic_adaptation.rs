//! Dynamic adaptability (§5.4) — replays the Fig. 12 experiments live,
//! entirely through the [`heye::platform`] facade.
//!
//! 1. **Bandwidth sweep (Fig. 12a/b)**: Orin AGX's uplink is throttled
//!    10 → 7.5 → 5 → 2.5 → 1 Gb/s. CloudVR keeps QoS by dropping the frame
//!    resolution; H-EYE re-balances tasks across the whole system and holds
//!    full resolution.
//! 2. **Device join (Fig. 12c)**: a new Xavier NX headset joins mid-run;
//!    the Orchestrator extends its hierarchy and serves the newcomer
//!    without disturbing existing devices' QoS.
//!
//! ```text
//! cargo run --release --example dynamic_adaptation
//! ```

use heye::hwgraph::presets::XAVIER_NX;
use heye::platform::{Platform, WorkloadSpec};
use heye::sim::{JoinEvent, SimConfig};
use heye::task::workloads::target_fps;
use heye::util::error::Result;

fn main() -> Result<()> {
    let platform = Platform::builder().paper_vr().build()?;
    bandwidth_sweep(&platform)?;
    device_join(&platform)?;
    Ok(())
}

/// Fig. 12a/b: step the Orin AGX uplink down and compare H-EYE's and
/// CloudVR's achieved FPS and frame resolution.
fn bandwidth_sweep(platform: &Platform) -> Result<()> {
    println!("== dynamic bandwidth (Fig. 12a/b): Orin AGX uplink sweep ==");
    println!(
        "{:>9} | {:>12} {:>12} | {:>12} {:>12}",
        "Gb/s", "heye FPS/tgt", "heye res", "cloudvr FPS/tgt", "cloudvr res"
    );
    for gbps in [10.0, 7.5, 5.0, 2.5, 1.0] {
        let mut row = Vec::new();
        for name in ["heye", "cloudvr"] {
            // edge0 = Orin AGX; its uplink is throttled from t = 0
            let report = platform
                .session(WorkloadSpec::Vr)
                .scheduler(name)
                .config(SimConfig::default().horizon(2.0).seed(42))
                .throttle_uplink(0, 0.0, Some(gbps))
                .run()?;
            let agx = report.decs.edge_devices[0];
            let target = target_fps(report.decs.device_model(agx));
            let achieved = report.achieved_fps(agx);
            let frames = report.metrics.frames_of(agx);
            let res: f64 = if frames.is_empty() {
                0.0
            } else {
                frames.iter().map(|f| f.resolution).sum::<f64>() / frames.len() as f64
            };
            row.push((achieved / target, res));
        }
        println!(
            "{:>9.1} | {:>12.2} {:>12.2} | {:>12.2} {:>12.2}",
            gbps, row[0].0, row[0].1, row[1].0, row[1].1
        );
    }
    println!("(H-EYE holds resolution 1.0 by re-balancing; CloudVR shrinks frames)");
    Ok(())
}

/// Fig. 12c: a Xavier NX joins at t = 1 s; report per-device QoS before
/// and after the join.
fn device_join(platform: &Platform) -> Result<()> {
    println!("\n== new edge joined (Fig. 12c): Xavier NX at t = 1.0 s ==");
    let t0 = std::time::Instant::now();
    let report = platform
        .session(WorkloadSpec::Vr)
        .scheduler("heye")
        .config(SimConfig::default().horizon(2.0).seed(42))
        .join(JoinEvent {
            t: 1.0,
            model: XAVIER_NX.to_string(),
            uplink_gbps: 10.0,
            vr_source: true,
        })
        .run()?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{:<10} {:>10} {:>12} {:>12}",
        "device", "frames", "qos-ok pre", "qos-ok post"
    );
    for &dev in &report.decs.edge_devices {
        let frames = report.metrics.frames_of(dev);
        if frames.is_empty() {
            continue;
        }
        let rate = |pre: bool| -> f64 {
            let sel: Vec<_> = frames
                .iter()
                .filter(|f| (f.release_t < 1.0) == pre)
                .collect();
            if sel.is_empty() {
                return f64::NAN;
            }
            sel.iter().filter(|f| f.qos_ok()).count() as f64 / sel.len() as f64
        };
        println!(
            "{:<10} {:>10} {:>11.0}% {:>11.0}%",
            report.decs.graph.node(dev).name,
            frames.len(),
            rate(true) * 100.0,
            rate(false) * 100.0
        );
    }
    println!(
        "newcomer scheduled within the run; whole 2 s simulation took {:.0} ms wall-clock \
         (rescheduling itself is sub-millisecond)",
        wall * 1e3
    );
    Ok(())
}
