//! Quickstart: a 60-line tour of the H-EYE public API.
//!
//! Builds the paper's testbed, asks the Orchestrator to place a render
//! task, predicts its latency with and without a co-runner, and runs one
//! short simulated second of the VR workload.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use heye::hwgraph::presets::{Decs, DecsSpec};
use heye::netsim::Network;
use heye::orchestrator::{Hierarchy, Loads, Orchestrator, Policy};
use heye::perfmodel::ProfileModel;
use heye::sim::{HeyeScheduler, SimConfig, Simulation, Workload};
use heye::slowdown::CachedSlowdown;
use heye::task::{workloads, TaskKind, TaskSpec};
use heye::traverser::Traverser;

fn main() {
    // 1. the HW-Graph: five Jetson-class edges + three servers (Table 2)
    let decs = Decs::build(&DecsSpec::paper_vr());
    println!(
        "DECS: {} nodes / {} links; edges={:?}",
        decs.graph.node_count(),
        decs.graph.edge_count(),
        decs.edge_devices.len()
    );

    // 2. the Traverser: contention-aware performance prediction
    let perf = ProfileModel::new();
    let net = Network::new();
    let slow = CachedSlowdown::new(&decs.graph);
    let tr = Traverser::new(&slow, &perf, &net);
    let cfg = workloads::vr_cfg(30.0, 1.0, None);
    let render_pu = decs.graph.by_name("server0.gpu").unwrap();
    let alone = tr
        .predict(&cfg, &full_mapping(&decs, render_pu), decs.edge_devices[0], &[], 0.0)
        .expect("feasible mapping");
    println!(
        "VR frame makespan on edge0+server0: {:.2} ms (slowdown {:.2} ms, comm {:.2} ms)",
        alone.makespan * 1e3,
        alone.slowdown_s.iter().sum::<f64>() * 1e3,
        alone.comm_s.iter().sum::<f64>() * 1e3
    );

    // 3. the Orchestrator: decentralized task placement (Alg. 1)
    let mut orc = Orchestrator::new(Hierarchy::from_decs(&decs), Policy::Hierarchical);
    let render = TaskSpec::new(TaskKind::Render).deadline(0.030);
    let r = orc.map_task(&tr, &render, decs.edge_devices[0], decs.edge_devices[0], 0.0, &Loads::default());
    let pu = r.pu.expect("render placed");
    println!(
        "render mapped to {} (predicted {:.2} ms, overhead {:.3} ms / {} hops)",
        decs.graph.node(pu).name,
        r.predicted_latency_s * 1e3,
        r.overhead.total_s() * 1e3,
        r.overhead.hops
    );

    // 4. the simulator: one simulated second of the full VR workload
    let mut sim = Simulation::new(Decs::build(&DecsSpec::paper_vr()));
    let mut sched = HeyeScheduler::new(Orchestrator::new(
        Hierarchy::from_decs(&sim.decs),
        Policy::Hierarchical,
    ));
    let wl = Workload::vr(&sim.decs);
    let m = sim.run(
        &mut sched,
        wl,
        vec![],
        vec![],
        &SimConfig::default().horizon(1.0),
    );
    println!(
        "1 s of VR: {} frames, mean latency {:.2} ms, QoS failures {:.1}%, \
         scheduling overhead {:.2}%",
        m.frames.len(),
        m.mean_latency_s() * 1e3,
        m.qos_failure_rate() * 100.0,
        m.overhead_ratio() * 100.0
    );
}

/// Map the 7-stage VR CFG: everything local to edge0 except render.
fn full_mapping(decs: &Decs, render_pu: heye::hwgraph::NodeId) -> Vec<heye::hwgraph::NodeId> {
    let n = |s: &str| decs.graph.by_name(s).unwrap();
    vec![
        n("edge0.cpu0"),
        n("edge0.cpu1"),
        render_pu,
        n("server0.cpu0"),
        n("edge0.vic"),
        n("edge0.vic"),
        n("edge0.cpu0"),
    ]
}
