//! Quickstart: the H-EYE public API in three steps — build a [`Platform`],
//! pick a scheduler from the registry, run a [`Session`], read the
//! [`RunReport`].
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use heye::platform::{Platform, SchedulerRegistry, WorkloadSpec};
use heye::sim::SimConfig;
use heye::util::error::Result;

fn main() -> Result<()> {
    // 1. the platform: the paper's testbed (five Jetson-class edges +
    //    three servers, Table 2), perf model, network — one builder call
    let platform = Platform::builder().paper_vr().build()?;
    let decs = platform.decs();
    println!(
        "DECS: {} nodes / {} links; {} edges + {} servers",
        decs.graph.node_count(),
        decs.graph.edge_count(),
        decs.edge_devices.len(),
        decs.servers.len()
    );

    // 2. the scheduler registry: H-EYE's policies and every baseline,
    //    resolvable by name (plug your own in with SchedulerRegistry::register)
    println!("\nregistered schedulers:");
    for e in SchedulerRegistry::entries() {
        println!("  {:<14} {}", e.name, e.description);
    }

    // 3. a session: one simulated second of the VR workload under H-EYE
    let report = platform
        .session(WorkloadSpec::Vr)
        .scheduler("heye")
        .config(SimConfig::default().horizon(1.0))
        .run()?;
    println!(
        "\n1 s of VR: {} frames, mean latency {:.2} ms, QoS failures {:.1}%, \
         scheduling overhead {:.2}%",
        report.frames(),
        report.mean_latency_s() * 1e3,
        report.qos_failure_rate() * 100.0,
        report.overhead_ratio() * 100.0
    );
    report.print_breakdown("per-device breakdown");

    // swapping the scheduler is the one-line change the registry exists for
    println!();
    platform
        .session(WorkloadSpec::Vr)
        .scheduler("ace")
        .config(SimConfig::default().horizon(1.0))
        .run()?
        .print_summary();
    Ok(())
}
