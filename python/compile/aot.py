"""AOT bridge: lower every L2 model to HLO **text** + a JSON manifest.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla_extension 0.5.1 the rust `xla` crate links against rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage::

    cd python && python -m compile.aot --out ../artifacts

Python runs exactly once here; the rust binary is self-contained afterwards.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import MODEL_SPECS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text, with return_tuple=True so the
    rust side always unwraps a tuple (see load path in rust/src/runtime)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str):
    spec = MODEL_SPECS[name]
    lowered = jax.jit(spec["fn"]).lower(*spec["inputs"])
    text = to_hlo_text(lowered)
    out_avals = lowered.out_info
    outputs = [
        {"shape": list(o.shape), "dtype": str(o.dtype)}
        for o in jax.tree_util.tree_leaves(out_avals)
    ]
    inputs = [
        {"shape": list(i.shape), "dtype": str(i.dtype)} for i in spec["inputs"]
    ]
    meta = {
        "app": spec["app"],
        "task": spec["task"],
        "flops": int(spec["flops"]),
        "inputs": inputs,
        "outputs": outputs,
        "hlo_file": f"{name}.hlo.txt",
        "hlo_sha256": hashlib.sha256(text.encode()).hexdigest(),
    }
    return text, meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--only", default=None, help="comma-separated subset of model names"
    )
    args = ap.parse_args()
    names = list(MODEL_SPECS) if args.only is None else args.only.split(",")
    os.makedirs(args.out, exist_ok=True)
    manifest = {"format": 1, "models": {}}
    for name in names:
        text, meta = lower_model(name)
        path = os.path.join(args.out, meta["hlo_file"])
        with open(path, "w") as f:
            f.write(text)
        manifest["models"][name] = meta
        print(f"  {name:<18} -> {path} ({len(text)} chars)")
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath} ({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
