"""L2: the H-EYE workload compute graphs, written in JAX on top of the L1
Pallas kernels.

Two applications from the paper (§4):

* **Mining (smart drill bits)** — three ML classifiers that each map a
  window of force-sensor samples to one of 8 rock classes: an MLP, an
  RBF-SVM and a KNN voter (Fig. 8).
* **Cloud-rendered VR** — the five-stage frame pipeline (Fig. 7): capture
  featurization + GRU pose prediction, speculative render, encode, decode,
  reproject, display.

Weights are deterministic (seeded) and *baked into the lowered HLO as
constants*, so the rust runtime only feeds the activation inputs. Every
function here is shape-polymorphic python; `aot.py` freezes the shapes
listed in `MODEL_SPECS` when lowering.
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.matmul import matmul
from .kernels.distance import pairwise_sqdist
from .kernels.gru import gru_cell
from .kernels.ref import sigmoid

# ---------------------------------------------------------------------------
# deterministic parameter construction
# ---------------------------------------------------------------------------

SEED = 0x48455945  # "HEYE"


def _rng(tag: str) -> np.random.Generator:
    return np.random.default_rng([SEED, sum(tag.encode())])


def _glorot(rng, shape):
    fan = sum(shape) / len(shape)
    return rng.normal(0.0, (1.0 / fan) ** 0.5, size=shape).astype(np.float32)


# mining dimensions: 64-sample force window -> 8 rock classes
FORCE_DIM = 64
N_CLASSES = 8
MLP_HIDDEN = (128, 64)
SVM_SV = 256
KNN_TRAIN = 512
KNN_K = 16

# VR dimensions
POSE_FEAT = 32
POSE_HIDDEN = 64
POSE_DOF = 6
FRAME = 256  # square frame side for the render/encode/decode/reproject proxies


def mlp_params():
    r = _rng("mlp")
    dims = (FORCE_DIM,) + MLP_HIDDEN + (N_CLASSES,)
    ws = [_glorot(r, (dims[i], dims[i + 1])) for i in range(len(dims) - 1)]
    bs = [np.zeros(dims[i + 1], np.float32) for i in range(len(dims) - 1)]
    return ws, bs


def svm_params():
    r = _rng("svm")
    sv = _glorot(r, (SVM_SV, FORCE_DIM))
    coef = _glorot(r, (SVM_SV, N_CLASSES))
    bias = np.zeros(N_CLASSES, np.float32)
    return sv, coef, bias


def knn_params():
    r = _rng("knn")
    train = _glorot(r, (KNN_TRAIN, FORCE_DIM))
    labels = np.eye(N_CLASSES, dtype=np.float32)[
        r.integers(0, N_CLASSES, size=KNN_TRAIN)
    ]
    return train, labels


def pose_params():
    r = _rng("pose")
    wx = _glorot(r, (POSE_FEAT, 3 * POSE_HIDDEN))
    wh = _glorot(r, (POSE_HIDDEN, 3 * POSE_HIDDEN))
    bx = np.zeros(3 * POSE_HIDDEN, np.float32)
    bh = np.zeros(3 * POSE_HIDDEN, np.float32)
    wp = _glorot(r, (POSE_HIDDEN, POSE_DOF))
    bp = np.zeros(POSE_DOF, np.float32)
    return wx, wh, bx, bh, wp, bp


def render_params():
    r = _rng("render")
    return _glorot(r, (FRAME, FRAME)), _glorot(r, (FRAME, FRAME))


def warp_params():
    # near-identity tri-diagonal warp (reprojection to the predicted pose)
    r = _rng("warp")
    w = np.eye(FRAME, dtype=np.float32) * 0.9
    w += 0.05 * np.roll(np.eye(FRAME, dtype=np.float32), 1, axis=1)
    w += 0.05 * np.roll(np.eye(FRAME, dtype=np.float32), -1, axis=1)
    return (w + 0.001 * _glorot(r, (FRAME, FRAME))).astype(np.float32)


def _dct_matrix(n: int) -> np.ndarray:
    """Orthonormal DCT-II basis, used by the encode/decode codec proxies."""
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    m = np.cos(np.pi * (2 * i + 1) * k / (2 * n)) * np.sqrt(2.0 / n)
    m[0] /= np.sqrt(2.0)
    return m.astype(np.float32)


# ---------------------------------------------------------------------------
# mining models
# ---------------------------------------------------------------------------


def mining_mlp(x):
    """3-layer MLP rock classifier over force windows; logits (b, 8)."""
    ws, bs = mlp_params()
    h = x
    for idx, (w, b) in enumerate(zip(ws, bs)):
        h = matmul(h, jnp.asarray(w)) + jnp.asarray(b)
        if idx + 1 < len(ws):
            h = jax.nn.relu(h)
    return (h,)


def mining_svm(x, gamma=0.05):
    """RBF-kernel SVM decision values: K(x, SV) @ coef + b."""
    sv, coef, bias = svm_params()
    d2 = pairwise_sqdist(x, jnp.asarray(sv))
    k = jnp.exp(-gamma * d2)
    return (matmul(k, jnp.asarray(coef)) + jnp.asarray(bias),)


def mining_knn(x):
    """Soft KNN vote: inverse-distance-weighted class scores of the k nearest.

    Formulated as sort + threshold mask rather than ``lax.top_k``: the
    ``topk`` HLO op grew a ``largest=`` attribute that the pinned
    xla_extension 0.5.1 text parser rejects, while ``sort`` round-trips.
    The mask formulation is numerically identical up to distance ties.
    """
    train, labels = knn_params()
    d2 = pairwise_sqdist(x, jnp.asarray(train))
    kth = jnp.sort(d2, axis=1)[:, KNN_K - 1 : KNN_K]  # (b, 1) k-th smallest
    w = (d2 <= kth).astype(jnp.float32) / (1.0 + d2)  # inverse-distance weights
    return (matmul(w, jnp.asarray(labels)),)


# ---------------------------------------------------------------------------
# VR pipeline models
# ---------------------------------------------------------------------------


def vr_pose_predict(feat, h):
    """GRU step over capture features -> (pose (b,6), next hidden (b,d))."""
    wx, wh, bx, bh, wp, bp = pose_params()
    h2 = gru_cell(
        feat, h, jnp.asarray(wx), jnp.asarray(wh), jnp.asarray(bx), jnp.asarray(bh)
    )
    pose = matmul(h2, jnp.asarray(wp)) + jnp.asarray(bp)
    return (pose, h2)


def vr_render(scene):
    """Speculative render proxy: two dense mixing layers over the scene."""
    w1, w2 = render_params()
    h = jnp.tanh(matmul(scene, jnp.asarray(w1)) / jnp.sqrt(jnp.float32(FRAME)))
    return (matmul(h, jnp.asarray(w2)) / jnp.sqrt(jnp.float32(FRAME)),)


_QSTEP = 0.25


def vr_encode(frame):
    """Codec proxy: orthonormal 2-D DCT + uniform quantization."""
    d = jnp.asarray(_dct_matrix(FRAME))
    coefs = matmul(matmul(d, frame), d.T)
    return (jnp.round(coefs / _QSTEP),)


def vr_decode(q):
    """Inverse of `vr_encode` (dequantize + inverse DCT)."""
    d = jnp.asarray(_dct_matrix(FRAME))
    return (matmul(matmul(d.T, q * _QSTEP), d),)


def vr_reproject(frame):
    """Reprojection proxy: near-identity learned warp to the predicted pose."""
    w = jnp.asarray(warp_params())
    return (matmul(w, frame),)


def vr_display(frame):
    """Display compositing proxy: gamma + clamp (elementwise, bandwidth-bound)."""
    x = jnp.clip(frame, -8.0, 8.0)
    return (sigmoid(x) * 255.0,)


# ---------------------------------------------------------------------------
# AOT specs: name -> (fn, example inputs, metadata)
# ---------------------------------------------------------------------------

MINING_BATCH = 32


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


MODEL_SPECS = {
    "mining_mlp": dict(
        fn=mining_mlp,
        inputs=[_f32(MINING_BATCH, FORCE_DIM)],
        app="mining",
        task="mlp",
        flops=2 * MINING_BATCH * (64 * 128 + 128 * 64 + 64 * 8),
    ),
    "mining_svm": dict(
        fn=mining_svm,
        inputs=[_f32(MINING_BATCH, FORCE_DIM)],
        app="mining",
        task="svm",
        flops=2 * MINING_BATCH * (SVM_SV * FORCE_DIM + SVM_SV * N_CLASSES),
    ),
    "mining_knn": dict(
        fn=mining_knn,
        inputs=[_f32(MINING_BATCH, FORCE_DIM)],
        app="mining",
        task="knn",
        flops=2 * MINING_BATCH * KNN_TRAIN * FORCE_DIM,
    ),
    "vr_pose_predict": dict(
        fn=vr_pose_predict,
        inputs=[_f32(1, POSE_FEAT), _f32(1, POSE_HIDDEN)],
        app="vr",
        task="pose_predict",
        flops=2 * (POSE_FEAT + POSE_HIDDEN) * 3 * POSE_HIDDEN,
    ),
    "vr_render": dict(
        fn=vr_render,
        inputs=[_f32(FRAME, FRAME)],
        app="vr",
        task="render",
        flops=2 * 2 * FRAME**3,
    ),
    "vr_encode": dict(
        fn=vr_encode,
        inputs=[_f32(FRAME, FRAME)],
        app="vr",
        task="encode",
        flops=2 * 2 * FRAME**3,
    ),
    "vr_decode": dict(
        fn=vr_decode,
        inputs=[_f32(FRAME, FRAME)],
        app="vr",
        task="decode",
        flops=2 * 2 * FRAME**3,
    ),
    "vr_reproject": dict(
        fn=vr_reproject,
        inputs=[_f32(FRAME, FRAME)],
        app="vr",
        task="reproject",
        flops=2 * FRAME**3,
    ),
    "vr_display": dict(
        fn=vr_display,
        inputs=[_f32(FRAME, FRAME)],
        app="vr",
        task="display",
        flops=4 * FRAME**2,
    ),
}
