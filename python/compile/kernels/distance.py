"""L1 Pallas kernel: tiled pairwise squared-L2 distance.

Backs the KNN mining task. TPU adaptation: the Gram term ``x @ y.T`` is the
dominant cost, so the kernel is organized exactly like the tiled matmul —
an (block_m x block_n) distance tile resident in VMEM per grid step — with
the row/col squared norms computed in-kernel from the same tiles, avoiding a
second pass over HBM (the fusion the paper's CUDA version got from shared
memory is expressed here as single-kernel VMEM reuse).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sqdist_kernel(x_ref, y_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xt = x_ref[...].astype(jnp.float32)  # (bm, bk)
    yt = y_ref[...].astype(jnp.float32)  # (bn, bk)
    xx = jnp.sum(xt * xt, axis=1, keepdims=True)  # (bm, 1)
    yy = jnp.sum(yt * yt, axis=1, keepdims=True).T  # (1, bn)
    xy = jnp.dot(xt, yt.T, preferred_element_type=jnp.float32)
    o_ref[...] += xx + yy - 2.0 * xy

    @pl.when(k == nk - 1)
    def _clamp():
        o_ref[...] = jnp.maximum(o_ref[...], 0.0)


def _ceil_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def pairwise_sqdist(x, y, *, block_m=128, block_n=128, block_k=128, interpret=True):
    """Squared L2 distances between rows of ``x (m,d)`` and ``y (n,d)``.

    Zero-padding the feature dimension is exact (padded coordinates add 0 to
    every distance); padded rows are sliced away.
    """
    m, d = x.shape
    n, d2 = y.shape
    assert d == d2, f"feature mismatch: {x.shape} vs {y.shape}"
    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 8))
    bk = min(block_k, _ceil_to(d, 8))
    mp, np_, dp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(d, bk)
    xp = jnp.zeros((mp, dp), jnp.float32).at[:m, :d].set(x.astype(jnp.float32))
    yp = jnp.zeros((np_, dp), jnp.float32).at[:n, :d].set(y.astype(jnp.float32))
    nk = dp // bk
    out = pl.pallas_call(
        functools.partial(_sqdist_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]
