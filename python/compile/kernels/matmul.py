"""L1 Pallas kernel: VMEM-tiled dense matmul.

TPU adaptation of the paper's dense-MM hot-spot (the microbenchmark behind
Fig. 2 and the compute core of the MLP / SVM / render tasks): instead of the
CUDA threadblock tiling the paper's Jetson targets use, the HBM<->VMEM
schedule is expressed with BlockSpecs — each grid step owns an
(block_m x block_n) output tile resident in VMEM and walks the K dimension,
accumulating partial products that ride the MXU (f32 accumulation).

Must run with ``interpret=True`` on CPU PJRT (Mosaic custom-calls only
execute on real TPUs); the lowered HLO is backend-portable.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: accumulate x[i,k] @ w[k,j] into o[i,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped partial product with explicit f32 accumulation.
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul(x, w, *, block_m=128, block_n=128, block_k=128, interpret=True):
    """``x (m,k) @ w (k,n) -> (m,n) f32`` via the tiled Pallas kernel.

    Arbitrary shapes are supported by zero-padding up to the block grid;
    padding contributes exact zeros to the accumulation.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 8))
    bk = min(block_k, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.zeros((mp, kp), jnp.float32).at[:m, :k].set(x.astype(jnp.float32))
    wp = jnp.zeros((kp, np_), jnp.float32).at[:k, :n].set(w.astype(jnp.float32))
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def vmem_footprint_bytes(block_m=128, block_n=128, block_k=128) -> int:
    """Estimated per-step VMEM residency (x-tile + w-tile + out-tile), bytes.

    Used by the §Perf roofline estimate in DESIGN.md: the default 128^3 f32
    blocking holds 3 * 128*128*4 = 196 KiB in VMEM, far under the ~16 MiB
    budget, leaving room for double buffering of both input streams.
    """
    return 4 * (block_m * block_k + block_k * block_n + block_m * block_n)
