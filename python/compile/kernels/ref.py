"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

Every kernel in this package must match its oracle to float32 tolerance for
all shapes/dtypes the hypothesis sweeps in ``python/tests`` generate.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain dense matmul with f32 accumulation."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(jnp.float32)


def pairwise_sqdist_ref(x, y):
    """Squared L2 distances between rows of ``x`` (m,d) and rows of ``y`` (n,d)."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (m, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T  # (1, n)
    xy = x @ y.T  # (m, n)
    # clamp: numerically the decomposition can dip epsilon-negative
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def sigmoid(v):
    return jnp.tanh(v * 0.5) * 0.5 + 0.5


def gru_cell_ref(x, h, wx, wh, bx, bh):
    """Fused GRU cell (PyTorch gate convention: r, z, n).

    x  : (b, i)  input features
    h  : (b, d)  previous hidden state
    wx : (i, 3d) input projection,   gates concatenated [r | z | n]
    wh : (d, 3d) hidden projection,  gates concatenated [r | z | n]
    bx : (3d,)   input bias
    bh : (3d,)   hidden bias
    returns (b, d) next hidden state
    """
    x = x.astype(jnp.float32)
    h = h.astype(jnp.float32)
    d = h.shape[1]
    gx = x @ wx.astype(jnp.float32) + bx.astype(jnp.float32)
    gh = h @ wh.astype(jnp.float32) + bh.astype(jnp.float32)
    rx, zx, nx = gx[:, :d], gx[:, d : 2 * d], gx[:, 2 * d :]
    rh, zh, nh = gh[:, :d], gh[:, d : 2 * d], gh[:, 2 * d :]
    r = sigmoid(rx + rh)
    z = sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    return (1.0 - z) * n + z * h
