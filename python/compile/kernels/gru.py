"""L1 Pallas kernel: fused GRU cell (the VR pose-prediction RNN step).

The paper's pose predictor is an RNN [49] running every frame on the edge.
A naive implementation round-trips HBM three times (two projections, then
the gate arithmetic). This kernel fuses the whole cell: both gate
projections ride the MXU from VMEM-resident tiles and the elementwise gate
math happens in-register before the single output store — the TPU analogue
of the CUDA "persistent-RNN" fusion.

Hidden sizes for this workload are small (<=256), so a single grid step
holds everything in VMEM; batching tiles over rows if b grows.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sigmoid(v):
    return jnp.tanh(v * 0.5) * 0.5 + 0.5


def _gru_kernel(x_ref, h_ref, wx_ref, wh_ref, bx_ref, bh_ref, o_ref, *, d: int):
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    gx = jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32) + bx_ref[...]
    gh = jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32) + bh_ref[...]
    r = _sigmoid(gx[:, :d] + gh[:, :d])
    z = _sigmoid(gx[:, d : 2 * d] + gh[:, d : 2 * d])
    n = jnp.tanh(gx[:, 2 * d :] + r * gh[:, 2 * d :])
    o_ref[...] = (1.0 - z) * n + z * h


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def gru_cell(x, h, wx, wh, bx, bh, *, block_b=128, interpret=True):
    """Next hidden state for a fused GRU cell; see ref.gru_cell_ref."""
    b, i = x.shape
    b2, d = h.shape
    assert b == b2 and wx.shape == (i, 3 * d) and wh.shape == (d, 3 * d)
    assert bx.shape == (3 * d,) and bh.shape == (3 * d,)
    bb = min(block_b, b)
    # pad batch to a multiple of the row block
    bp = (b + bb - 1) // bb * bb
    xp = jnp.zeros((bp, i), jnp.float32).at[:b].set(x.astype(jnp.float32))
    hp = jnp.zeros((bp, d), jnp.float32).at[:b].set(h.astype(jnp.float32))
    out = pl.pallas_call(
        functools.partial(_gru_kernel, d=d),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, i), lambda r: (r, 0)),
            pl.BlockSpec((bb, d), lambda r: (r, 0)),
            pl.BlockSpec((i, 3 * d), lambda r: (0, 0)),
            pl.BlockSpec((d, 3 * d), lambda r: (0, 0)),
            pl.BlockSpec((3 * d,), lambda r: (0,)),
            pl.BlockSpec((3 * d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, d), jnp.float32),
        interpret=interpret,
    )(
        xp,
        hp,
        wx.astype(jnp.float32),
        wh.astype(jnp.float32),
        bx.astype(jnp.float32),
        bh.astype(jnp.float32),
    )
    return out[:b]
