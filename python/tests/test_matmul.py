"""L1 correctness: Pallas tiled matmul vs the pure-jnp oracle.

Hypothesis sweeps shapes (including non-multiples of the block sizes, which
exercise the zero-padding path), block shapes, and input dtypes.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, strategies as st

from compile.kernels.matmul import matmul, vmem_footprint_bytes
from compile.kernels.ref import matmul_ref

dims = st.integers(min_value=1, max_value=96)
blocks = st.sampled_from([8, 16, 32, 64, 128])
dtypes = st.sampled_from([np.float32, np.float16])


@given(m=dims, k=dims, n=dims, bm=blocks, bn=blocks, bk=blocks, dt=dtypes)
def test_matmul_matches_ref(m, k, n, bm, bn, bk, dt):
    rng = np.random.default_rng([m, k, n, bm])
    x = rng.normal(size=(m, k)).astype(dt)
    w = rng.normal(size=(k, n)).astype(dt)
    got = matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
    want = matmul_ref(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)
    assert got.dtype == jnp.float32


def test_matmul_exact_blocks():
    rng = np.random.default_rng(7)
    x = rng.normal(size=(128, 128)).astype(np.float32)
    w = rng.normal(size=(128, 128)).astype(np.float32)
    got = matmul(x, w)
    np.testing.assert_allclose(np.asarray(got), x @ w, rtol=1e-4, atol=1e-4)


def test_matmul_identity():
    x = np.arange(64 * 48, dtype=np.float32).reshape(64, 48)
    got = matmul(x, np.eye(48, dtype=np.float32), block_m=32, block_n=32, block_k=32)
    np.testing.assert_allclose(np.asarray(got), x, rtol=0, atol=0)


def test_matmul_zero_padding_is_exact():
    # shapes deliberately prime, far off the block grid
    rng = np.random.default_rng(11)
    x = rng.normal(size=(13, 17)).astype(np.float32)
    w = rng.normal(size=(17, 7)).astype(np.float32)
    got = matmul(x, w, block_m=128, block_n=128, block_k=128)
    np.testing.assert_allclose(np.asarray(got), x @ w, rtol=1e-5, atol=1e-5)


def test_matmul_rejects_contraction_mismatch():
    x = np.zeros((4, 5), np.float32)
    w = np.zeros((6, 3), np.float32)
    try:
        matmul(x, w)
        raise AssertionError("expected shape-mismatch failure")
    except AssertionError as e:
        assert "contraction mismatch" in str(e)


def test_vmem_footprint_default_blocking_fits_budget():
    # default 128^3 f32 blocking: 3 tiles * 64 KiB = 192 KiB << 16 MiB VMEM
    fp = vmem_footprint_bytes()
    assert fp == 3 * 128 * 128 * 4
    assert fp < 16 * 2**20 // 4  # room for 4x double-buffering
