"""L2 correctness: each workload model vs an independent pure-jnp replica,
plus shape/determinism contracts the rust runtime relies on."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model as M
from compile.kernels.ref import pairwise_sqdist_ref, gru_cell_ref, sigmoid


def _x(b=M.MINING_BATCH, d=M.FORCE_DIM, seed=0):
    return np.random.default_rng(seed).normal(size=(b, d)).astype(np.float32)


def test_mlp_matches_jnp_replica():
    x = _x()
    ws, bs = M.mlp_params()
    h = jnp.asarray(x)
    for i, (w, b) in enumerate(zip(ws, bs)):
        h = h @ w + b
        if i + 1 < len(ws):
            h = jax.nn.relu(h)
    (got,) = M.mining_mlp(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(h), rtol=1e-4, atol=1e-4)
    assert got.shape == (M.MINING_BATCH, M.N_CLASSES)


def test_svm_matches_jnp_replica():
    x = _x(seed=1)
    sv, coef, bias = M.svm_params()
    k = jnp.exp(-0.05 * pairwise_sqdist_ref(jnp.asarray(x), jnp.asarray(sv)))
    want = k @ coef + bias
    (got,) = M.mining_svm(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_knn_matches_jnp_replica():
    x = _x(seed=2)
    train, labels = M.knn_params()
    d2 = pairwise_sqdist_ref(jnp.asarray(x), jnp.asarray(train))
    neg, idx = jax.lax.top_k(-d2, M.KNN_K)
    w = 1.0 / (1.0 - neg)
    want = jnp.einsum("bk,bkc->bc", w, jnp.asarray(labels)[idx])
    (got,) = M.mining_knn(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_knn_scores_are_probability_like():
    (got,) = M.mining_knn(_x(seed=3))
    s = np.asarray(got)
    assert (s >= 0).all()
    # scores sum to the total vote mass (sum of weights), strictly positive
    assert (s.sum(axis=1) > 0).all()


def test_pose_predict_matches_replica_and_updates_state():
    feat = np.random.default_rng(4).normal(size=(1, M.POSE_FEAT)).astype(np.float32)
    h0 = np.zeros((1, M.POSE_HIDDEN), np.float32)
    wx, wh, bx, bh, wp, bp = M.pose_params()
    h1 = gru_cell_ref(*(jnp.asarray(a) for a in (feat, h0, wx, wh, bx, bh)))
    pose_want = h1 @ wp + bp
    pose, h1_got = M.vr_pose_predict(feat, h0)
    np.testing.assert_allclose(np.asarray(h1_got), np.asarray(h1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(pose), np.asarray(pose_want), rtol=1e-4, atol=1e-4
    )
    assert not np.allclose(np.asarray(h1_got), h0)  # state actually evolved


def test_encode_decode_roundtrip_error_bounded_by_qstep():
    frame = (
        np.random.default_rng(5).normal(size=(M.FRAME, M.FRAME)).astype(np.float32)
    )
    (q,) = M.vr_encode(frame)
    (rec,) = M.vr_decode(np.asarray(q))
    # orthonormal DCT preserves the Frobenius norm, and the per-coefficient
    # quantization error is <= qstep/2, so the pixel-domain RMS error is
    # bounded by qstep/2 = 0.125
    err = np.asarray(rec) - frame
    rms = np.sqrt((err**2).mean())
    assert rms <= 0.125 + 1e-4, f"round-trip RMS {rms} exceeds quantization bound"


def test_encode_output_is_integer_grid():
    frame = (
        np.random.default_rng(6).normal(size=(M.FRAME, M.FRAME)).astype(np.float32)
    )
    (q,) = M.vr_encode(frame)
    q = np.asarray(q)
    np.testing.assert_allclose(q, np.round(q), atol=0)


def test_render_is_deterministic_and_bounded_growth():
    scene = (
        np.random.default_rng(7).normal(size=(M.FRAME, M.FRAME)).astype(np.float32)
    )
    (a,) = M.vr_render(scene)
    (b,) = M.vr_render(scene)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reproject_near_identity_warp():
    frame = np.ones((M.FRAME, M.FRAME), np.float32)
    (out,) = M.vr_reproject(frame)
    # row-stochastic-ish warp keeps a constant frame roughly constant
    assert abs(np.asarray(out).mean() - 1.0) < 0.1


def test_display_range():
    frame = (
        np.random.default_rng(8).normal(scale=10, size=(M.FRAME, M.FRAME))
    ).astype(np.float32)
    (out,) = M.vr_display(frame)
    out = np.asarray(out)
    assert out.min() >= 0.0 and out.max() <= 255.0
    # monotone: brighter input -> brighter output
    ramp = np.linspace(-8, 8, M.FRAME, dtype=np.float32)[None, :].repeat(M.FRAME, 0)
    (o,) = M.vr_display(ramp)
    o = np.asarray(o)
    assert (np.diff(o[0]) >= -1e-4).all()


def test_display_matches_sigmoid_formula():
    frame = np.array([[0.0, 8.0, -8.0, 100.0]], np.float32)
    (out,) = M.vr_display(frame)
    want = np.asarray(sigmoid(jnp.clip(jnp.asarray(frame), -8, 8))) * 255.0
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5)


def test_model_specs_cover_both_apps_and_all_pipeline_stages():
    apps = {s["app"] for s in M.MODEL_SPECS.values()}
    assert apps == {"mining", "vr"}
    vr_tasks = {s["task"] for s in M.MODEL_SPECS.values() if s["app"] == "vr"}
    assert vr_tasks == {
        "pose_predict",
        "render",
        "encode",
        "decode",
        "reproject",
        "display",
    }
    mining_tasks = {s["task"] for s in M.MODEL_SPECS.values() if s["app"] == "mining"}
    assert mining_tasks == {"svm", "knn", "mlp"}
