"""AOT contract tests: HLO text artifacts + manifest the rust runtime loads."""

import hashlib
import json
import os

import pytest

from compile import model as M
from compile.aot import lower_model, to_hlo_text

import jax
import jax.numpy as jnp

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_model_emits_parseable_hlo_text():
    text, meta = lower_model("vr_display")
    assert "ENTRY" in text and "ROOT" in text
    assert meta["hlo_sha256"] == hashlib.sha256(text.encode()).hexdigest()
    assert meta["app"] == "vr" and meta["task"] == "display"
    assert meta["inputs"] == [{"shape": [M.FRAME, M.FRAME], "dtype": "float32"}]


def test_lowered_hlo_contains_no_custom_calls():
    # interpret=True pallas must lower to plain HLO ops the CPU PJRT can run
    for name in ("mining_mlp", "vr_render", "vr_pose_predict"):
        text, _ = lower_model(name)
        assert "custom-call" not in text, f"{name} emitted a custom-call"


def test_manifest_consistent_with_artifacts_on_disk():
    mpath = os.path.join(ART, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("run `make artifacts` first")
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["format"] == 1
    assert set(manifest["models"]) == set(M.MODEL_SPECS)
    for name, meta in manifest["models"].items():
        path = os.path.join(ART, meta["hlo_file"])
        assert os.path.exists(path), f"missing artifact {path}"
        with open(path) as f:
            text = f.read()
        assert hashlib.sha256(text.encode()).hexdigest() == meta["hlo_sha256"], (
            f"{name}: artifact drifted from manifest — re-run `make artifacts`"
        )
        spec = M.MODEL_SPECS[name]
        assert meta["flops"] == int(spec["flops"])
        got_shapes = [tuple(i["shape"]) for i in meta["inputs"]]
        want_shapes = [tuple(i.shape) for i in spec["inputs"]]
        assert got_shapes == want_shapes


def test_output_arity_matches_manifest():
    text, meta = lower_model("vr_pose_predict")
    assert len(meta["outputs"]) == 2  # (pose, hidden)
    assert tuple(meta["outputs"][0]["shape"]) == (1, M.POSE_DOF)
    assert tuple(meta["outputs"][1]["shape"]) == (1, M.POSE_HIDDEN)


def test_hlo_text_roundtrip_stable():
    # lowering the same model twice yields identical text (determinism the
    # manifest sha + rust-side caching rely on)
    t1, _ = lower_model("mining_svm")
    t2, _ = lower_model("mining_svm")
    assert t1 == t2


def test_to_hlo_text_tuple_return():
    lowered = jax.jit(lambda x: (x + 1.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    # return_tuple=True wraps in a tuple even for single outputs
    assert "tuple(" in text or "(f32[2,2]" in text
