import os
import sys

import numpy as np
import pytest

# allow `compile.*` imports when pytest is run from python/ or the repo root
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from hypothesis import settings

# interpret-mode pallas is slow; keep sweeps bounded but meaningful
settings.register_profile("heye", max_examples=25, deadline=None)
settings.load_profile("heye")


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
