"""L1 correctness: tiled pairwise squared-L2 distance vs oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, strategies as st

from compile.kernels.distance import pairwise_sqdist
from compile.kernels.ref import pairwise_sqdist_ref

dims = st.integers(min_value=1, max_value=80)
blocks = st.sampled_from([8, 16, 32, 64])


@given(m=dims, n=dims, d=dims, bm=blocks, bn=blocks, bk=blocks)
def test_sqdist_matches_ref(m, n, d, bm, bn, bk):
    rng = np.random.default_rng([m, n, d])
    x = rng.normal(size=(m, d)).astype(np.float32)
    y = rng.normal(size=(n, d)).astype(np.float32)
    got = pairwise_sqdist(x, y, block_m=bm, block_n=bn, block_k=bk)
    want = pairwise_sqdist_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_sqdist_nonnegative_and_zero_diagonal():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(24, 40)).astype(np.float32)
    d2 = np.asarray(pairwise_sqdist(x, x, block_m=8, block_n=8, block_k=8))
    assert (d2 >= 0).all()
    np.testing.assert_allclose(np.diag(d2), 0.0, atol=1e-3)


def test_sqdist_symmetry():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(10, 33)).astype(np.float32)
    y = rng.normal(size=(21, 33)).astype(np.float32)
    a = np.asarray(pairwise_sqdist(x, y, block_m=16, block_n=16, block_k=16))
    b = np.asarray(pairwise_sqdist(y, x, block_m=16, block_n=16, block_k=16))
    np.testing.assert_allclose(a, b.T, rtol=1e-5, atol=1e-5)


def test_sqdist_known_values():
    x = np.array([[0.0, 0.0], [1.0, 1.0]], np.float32)
    y = np.array([[0.0, 0.0], [3.0, 4.0]], np.float32)
    got = np.asarray(pairwise_sqdist(x, y, block_m=8, block_n=8, block_k=8))
    np.testing.assert_allclose(got, [[0.0, 25.0], [2.0, 13.0]], atol=1e-5)
