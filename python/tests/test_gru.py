"""L1 correctness: fused GRU cell vs oracle + gate-math invariants."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, strategies as st

from compile.kernels.gru import gru_cell
from compile.kernels.ref import gru_cell_ref


def _inputs(b, i, d, seed):
    rng = np.random.default_rng(seed)
    return (
        rng.normal(size=(b, i)).astype(np.float32),
        rng.normal(size=(b, d)).astype(np.float32),
        rng.normal(scale=0.3, size=(i, 3 * d)).astype(np.float32),
        rng.normal(scale=0.3, size=(d, 3 * d)).astype(np.float32),
        rng.normal(scale=0.1, size=(3 * d,)).astype(np.float32),
        rng.normal(scale=0.1, size=(3 * d,)).astype(np.float32),
    )


@given(
    b=st.integers(1, 48),
    i=st.integers(1, 48),
    d=st.integers(1, 48),
    bb=st.sampled_from([4, 16, 64, 128]),
)
def test_gru_matches_ref(b, i, d, bb):
    args = _inputs(b, i, d, seed=[b, i, d])
    got = gru_cell(*args, block_b=bb)
    want = gru_cell_ref(*(jnp.asarray(a) for a in args))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_gru_zero_update_gate_keeps_candidate_bounded():
    # with all weights/bias zero except huge z-bias, h' ~= h (update gate ~1)
    b, i, d = 3, 8, 16
    x = np.random.default_rng(0).normal(size=(b, i)).astype(np.float32)
    h = np.random.default_rng(1).normal(size=(b, d)).astype(np.float32)
    wx = np.zeros((i, 3 * d), np.float32)
    wh = np.zeros((d, 3 * d), np.float32)
    bx = np.zeros(3 * d, np.float32)
    bx[d : 2 * d] = 50.0  # z -> sigmoid(50) ~ 1
    bh = np.zeros(3 * d, np.float32)
    out = np.asarray(gru_cell(x, h, wx, wh, bx, bh))
    np.testing.assert_allclose(out, h, rtol=1e-4, atol=1e-4)


def test_gru_output_is_convex_combination_bound():
    # |h'| <= max(|h|, 1): output is z*h + (1-z)*tanh(...)
    args = _inputs(16, 24, 32, seed=9)
    out = np.asarray(gru_cell(*args))
    bound = np.maximum(np.abs(args[1]), 1.0) + 1e-5
    assert (np.abs(out) <= bound).all()


def test_gru_batch_padding_consistency():
    # result must not depend on the block size / padding amount
    args = _inputs(7, 12, 20, seed=2)
    a = np.asarray(gru_cell(*args, block_b=4))
    b = np.asarray(gru_cell(*args, block_b=128))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
